//! Iteration planning: continuous batching + chunked prefill (paper §3.2
//! local scheduler, §3.3 optimized batch processing).
//!
//! Per iteration the local scheduler builds a batch under a token budget:
//! (i) all running decode requests join first; (ii) then partially
//! computed chunked-prefill requests; (iii) then new prefill chunks;
//! (iv) encode tasks only when no prefill work is pending (the §3.3 rule
//! "new requests' encoding phases are processed only when no requests are
//! in the prefill phase").  Online requests may preempt offline ones.

use crate::coordinator::request::{Phase, Request, RequestId};

/// Batch limits for one instance.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Max sequences decoded per iteration.
    pub max_decode_seqs: usize,
    /// Prefill token budget per iteration (chunked prefill).
    pub token_budget: u64,
    /// Max encode images per iteration (from the EPD profiler).
    pub max_encode_batch: usize,
    /// Instance KV capacity in tokens.
    pub kv_capacity_tokens: u64,
    /// Cap on concurrently active sequences (running + newly admitted
    /// prefills).  Backends with physical batch slots (the PJRT server)
    /// set this to their slot count; the simulator leaves it unbounded.
    pub max_seqs: usize,
    /// Token-exact admission (LightLLM-style): prefill chunks are
    /// admitted — and shrunk — against the instance's real free KV
    /// tokens (capacity − resident context − one reserved growth token
    /// per planned decode), and the `max_seqs` slot heuristic stops
    /// binding.  Off by default; the legacy path is bit-identical.
    pub token_admission: bool,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig {
            max_decode_seqs: 64,
            token_budget: 1024,
            max_encode_batch: 8,
            kv_capacity_tokens: 2_000_000,
            max_seqs: usize::MAX,
            token_admission: false,
        }
    }
}

/// The work selected for one forward iteration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct IterationPlan {
    pub decode_ids: Vec<RequestId>,
    /// (request, tokens to prefill this iteration, existing context)
    pub prefill_chunks: Vec<(RequestId, u64, u64)>,
    pub encode_ids: Vec<RequestId>,
    /// Offline requests evicted to make room for online ones.
    pub preempted: Vec<RequestId>,
    /// Tokens admitted this iteration beyond the instance's free KV
    /// capacity at admission time (free = capacity − resident context −
    /// one growth token per planned decode).  Observational under the
    /// legacy slot heuristic; zero by construction under
    /// `token_admission`.
    pub overcommit_tokens: u64,
}

impl IterationPlan {
    pub fn is_empty(&self) -> bool {
        self.decode_ids.is_empty() && self.prefill_chunks.is_empty() && self.encode_ids.is_empty()
    }

    pub fn prefill_tokens(&self) -> u64 {
        self.prefill_chunks.iter().map(|(_, t, _)| t).sum()
    }
}

/// Assemble the next iteration from an instance's work set.
///
/// `running` — requests in Decode on this instance (insertion order);
/// `queued`  — requests in Prefill (FCFS order, online before offline
///             enforced here);
/// `encodes` — multimodal requests in Encode.
pub fn plan_iteration(
    running: &[&Request],
    queued: &[&Request],
    encodes: &[&Request],
    cfg: &BatchConfig,
) -> IterationPlan {
    let mut plan = IterationPlan::default();
    let mut kv_tokens: u64 = running.iter().map(|r| r.context_len()).sum();

    // (i) running decodes first, preferring online when over capacity
    let mut decode_order: Vec<&&Request> = running.iter().collect();
    decode_order.sort_by_key(|r| (!r.is_online(), r.id));
    for r in decode_order {
        debug_assert!(matches!(r.phase, Phase::Decode));
        if plan.decode_ids.len() < cfg.max_decode_seqs {
            plan.decode_ids.push(r.id);
        } else if !r.is_online() {
            plan.preempted.push(r.id);
        } else {
            // online overflow: preempt the last offline decode if any
            if let Some(pos) = plan
                .decode_ids
                .iter()
                .rposition(|id| running.iter().any(|q| q.id == *id && !q.is_online()))
            {
                let evicted = plan.decode_ids.remove(pos);
                plan.preempted.push(evicted);
                plan.decode_ids.push(r.id);
            }
        }
    }

    // (ii)+(iii) chunked prefill under the token budget: online FCFS first,
    // then offline; partially computed requests keep priority by arrival.
    let decode_growth = plan.decode_ids.len() as u64;
    let kv_resident = kv_tokens;
    let mut budget = cfg.token_budget;
    let mut queue_order: Vec<&&Request> = queued.iter().collect();
    queue_order.sort_by_key(|r| {
        (
            !r.is_online(),
            // partially-prefilled requests first within a class
            r.prefilled == 0 && r.prefix_hit_tokens == 0,
            r.id,
        )
    });
    for r in queue_order {
        debug_assert!(matches!(r.phase, Phase::Prefill));
        if budget == 0 {
            break;
        }
        // slot admission: a prefilled sequence occupies an active slot
        // until completion, so admit only while slots remain (token
        // admission replaces this heuristic with the KV budget below)
        if !cfg.token_admission && running.len() + plan.prefill_chunks.len() >= cfg.max_seqs {
            break;
        }
        let want = r.prefill_remaining();
        if want == 0 {
            continue;
        }
        // KV admission: the chunk's tokens must fit
        let mut chunk = want.min(budget);
        if cfg.token_admission {
            // token-exact: shrink to the real free KV tokens, reserving
            // a growth token for every decode planned this iteration
            chunk = chunk.min(cfg.kv_capacity_tokens.saturating_sub(kv_tokens + decode_growth));
            if chunk == 0 {
                continue;
            }
        } else if kv_tokens + chunk > cfg.kv_capacity_tokens {
            continue;
        }
        let ctx = r.context_len();
        plan.prefill_chunks.push((r.id, chunk, ctx));
        kv_tokens += chunk;
        budget -= chunk;
    }

    // admission-overcommit accounting: admitted prefill tokens beyond
    // the free KV at admission time
    let free = cfg.kv_capacity_tokens.saturating_sub(kv_resident + decode_growth);
    plan.overcommit_tokens = plan.prefill_tokens().saturating_sub(free);

    // (iv) encode only when no prefill work was scheduled or pending
    if plan.prefill_chunks.is_empty() && queued.iter().all(|r| r.prefill_remaining() == 0) {
        for r in encodes.iter().take(cfg.max_encode_batch) {
            debug_assert!(matches!(r.phase, Phase::Encode));
            plan.encode_ids.push(r.id);
        }
    }

    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Slo;
    use crate::workload::RequestSpec;

    fn online(id: RequestId, input: u64, output: u64) -> Request {
        Request::new(id, RequestSpec::text(0.0, input, output), Slo::UNCONSTRAINED)
    }

    fn offline(id: RequestId, input: u64, output: u64) -> Request {
        Request::new(id, RequestSpec::text(0.0, input, output).offline(), Slo::UNCONSTRAINED)
    }

    fn decoding(mut r: Request) -> Request {
        let inp = r.spec.input_tokens;
        r.advance_prefill(inp, 0.0);
        r
    }

    #[test]
    fn decodes_join_first_then_prefill_chunks() {
        let d1 = decoding(online(1, 10, 5));
        let d2 = decoding(online(2, 10, 5));
        let p1 = online(3, 500, 5);
        let cfg = BatchConfig { token_budget: 256, ..Default::default() };
        let plan = plan_iteration(&[&d1, &d2], &[&p1], &[], &cfg);
        assert_eq!(plan.decode_ids, vec![1, 2]);
        assert_eq!(plan.prefill_chunks, vec![(3, 256, 0)]);
    }

    #[test]
    fn chunk_respects_budget_across_requests() {
        let p1 = online(1, 100, 5);
        let p2 = online(2, 300, 5);
        let cfg = BatchConfig { token_budget: 250, ..Default::default() };
        let plan = plan_iteration(&[], &[&p1, &p2], &[], &cfg);
        assert_eq!(plan.prefill_chunks, vec![(1, 100, 0), (2, 150, 0)]);
        assert_eq!(plan.prefill_tokens(), 250);
    }

    #[test]
    fn partial_prefill_has_priority() {
        let mut p1 = online(1, 400, 5);
        p1.advance_prefill(100, 0.0); // partially computed
        let p2 = online(2, 100, 5);
        let cfg = BatchConfig { token_budget: 200, ..Default::default() };
        let plan = plan_iteration(&[], &[&p2, &p1], &[], &cfg);
        assert_eq!(plan.prefill_chunks[0].0, 1, "partially-computed chunk must resume first");
        assert_eq!(plan.prefill_chunks[0].2, 100, "context carried");
    }

    #[test]
    fn online_prefill_precedes_offline() {
        let off = offline(1, 200, 5);
        let on = online(2, 200, 5);
        let cfg = BatchConfig { token_budget: 200, ..Default::default() };
        let plan = plan_iteration(&[], &[&off, &on], &[], &cfg);
        assert_eq!(plan.prefill_chunks[0].0, 2);
    }

    #[test]
    fn encode_only_when_no_prefill_pending() {
        let mut spec = RequestSpec::text(0.0, 10, 5);
        spec.image_patches = 64;
        let e = Request::new(1, spec, Slo::UNCONSTRAINED);
        let p = online(2, 100, 5);
        let cfg = BatchConfig::default();
        let with_prefill = plan_iteration(&[], &[&p], &[&e], &cfg);
        assert!(with_prefill.encode_ids.is_empty());
        let without = plan_iteration(&[], &[], &[&e], &cfg);
        assert_eq!(without.encode_ids, vec![1]);
    }

    #[test]
    fn online_decode_preempts_offline_when_full() {
        let cfg = BatchConfig { max_decode_seqs: 2, ..Default::default() };
        let d_off = decoding(offline(1, 10, 5));
        let d_on1 = decoding(online(2, 10, 5));
        let d_on2 = decoding(online(3, 10, 5));
        let plan = plan_iteration(&[&d_off, &d_on1, &d_on2], &[], &[], &cfg);
        assert_eq!(plan.decode_ids.len(), 2);
        assert!(plan.decode_ids.contains(&2) && plan.decode_ids.contains(&3));
        assert_eq!(plan.preempted, vec![1]);
    }

    #[test]
    fn kv_capacity_gates_admission() {
        let d = decoding(online(1, 1000, 5));
        let p = online(2, 500, 5);
        let cfg = BatchConfig { kv_capacity_tokens: 1100, token_budget: 500, ..Default::default() };
        let plan = plan_iteration(&[&d], &[&p], &[], &cfg);
        assert!(plan.prefill_chunks.is_empty(), "chunk would exceed KV capacity");
    }

    #[test]
    fn max_seqs_gates_prefill_admission() {
        let d1 = decoding(online(1, 10, 5));
        let d2 = decoding(online(2, 10, 5));
        let p1 = online(3, 100, 5);
        let p2 = online(4, 100, 5);
        let cfg = BatchConfig { max_seqs: 3, token_budget: 1024, ..Default::default() };
        let plan = plan_iteration(&[&d1, &d2], &[&p1, &p2], &[], &cfg);
        assert_eq!(plan.decode_ids, vec![1, 2]);
        assert_eq!(plan.prefill_chunks.len(), 1, "only one slot free: {plan:?}");
        assert_eq!(plan.prefill_chunks[0].0, 3);
    }

    #[test]
    fn token_admission_replaces_the_slot_heuristic() {
        let d1 = decoding(online(1, 10, 5));
        let d2 = decoding(online(2, 10, 5));
        let p1 = online(3, 100, 5);
        let p2 = online(4, 100, 5);
        let cfg = BatchConfig {
            max_seqs: 3,
            token_budget: 1024,
            token_admission: true,
            ..Default::default()
        };
        let plan = plan_iteration(&[&d1, &d2], &[&p1, &p2], &[], &cfg);
        assert_eq!(plan.prefill_chunks.len(), 2, "KV budget binds, not slots: {plan:?}");
        assert_eq!(plan.overcommit_tokens, 0);
    }

    #[test]
    fn token_admission_shrinks_chunks_to_free_kv() {
        // 1000 resident + 1 reserved decode-growth token: 99 tokens free
        let d = decoding(online(1, 1000, 5));
        let p = online(2, 500, 5);
        let cfg = BatchConfig {
            kv_capacity_tokens: 1100,
            token_budget: 500,
            token_admission: true,
            ..Default::default()
        };
        let plan = plan_iteration(&[&d], &[&p], &[], &cfg);
        assert_eq!(plan.prefill_chunks, vec![(2, 99, 0)], "chunk shrinks to exact free KV");
        assert_eq!(plan.overcommit_tokens, 0);
    }

    #[test]
    fn legacy_admission_can_overcommit_the_decode_reserve() {
        // legacy checks chunks against raw capacity, ignoring decode
        // growth: with 1000 resident, one decode, and 10 free raw
        // tokens, a 10-token chunk is one token of overcommit
        let d = decoding(online(1, 1000, 5));
        let p = online(2, 10, 5);
        let cfg = BatchConfig { kv_capacity_tokens: 1010, token_budget: 10, ..Default::default() };
        let plan = plan_iteration(&[&d], &[&p], &[], &cfg);
        assert_eq!(plan.prefill_chunks, vec![(2, 10, 0)]);
        assert_eq!(plan.overcommit_tokens, 1, "decode growth was not reserved");
        // token admission shrinks the chunk and stays exact
        let plan = plan_iteration(&[&d], &[&p], &[], &BatchConfig { token_admission: true, ..cfg });
        assert_eq!(plan.prefill_chunks, vec![(2, 9, 0)]);
        assert_eq!(plan.overcommit_tokens, 0);
    }

    #[test]
    fn property_token_admission_never_overcommits() {
        crate::testutil::check("token-admission-exact", 96, |rng| {
            let cfg = BatchConfig {
                kv_capacity_tokens: rng.range(1, 2048),
                token_budget: rng.range(1, 512),
                max_decode_seqs: rng.range(1, 8) as usize,
                token_admission: true,
                ..Default::default()
            };
            let running: Vec<Request> = (0..rng.range(0, 6))
                .map(|i| decoding(online(i, rng.range(1, 600), 5)))
                .collect();
            let queued: Vec<Request> = (0..rng.range(0, 8))
                .map(|i| online(100 + i, rng.range(1, 1000), 5))
                .collect();
            let run_refs: Vec<&Request> = running.iter().collect();
            let q_refs: Vec<&Request> = queued.iter().collect();
            let plan = plan_iteration(&run_refs, &q_refs, &[], &cfg);
            crate::prop_assert!(
                plan.overcommit_tokens == 0,
                "token admission overcommitted by {}",
                plan.overcommit_tokens
            );
            let resident: u64 = running.iter().map(|r| r.context_len()).sum();
            let admitted = plan.prefill_tokens();
            let reserve = plan.decode_ids.len() as u64;
            crate::prop_assert!(
                admitted <= cfg.kv_capacity_tokens.saturating_sub(resident + reserve),
                "admitted {admitted} tokens past free capacity"
            );
            crate::prop_assert!(admitted <= cfg.token_budget, "budget exceeded");
            Ok(())
        });
    }

    #[test]
    fn property_budget_never_exceeded() {
        crate::testutil::quickcheck("budget-bound", |rng| {
            let budget = rng.range(1, 512);
            let cfg = BatchConfig { token_budget: budget, ..Default::default() };
            let reqs: Vec<Request> = (0..rng.range(1, 10))
                .map(|i| online(i, rng.range(1, 1000), 5))
                .collect();
            let refs: Vec<&Request> = reqs.iter().collect();
            let plan = plan_iteration(&[], &refs, &[], &cfg);
            crate::prop_assert!(
                plan.prefill_tokens() <= budget,
                "tokens {} > budget {}",
                plan.prefill_tokens(),
                budget
            );
            for (_, t, _) in &plan.prefill_chunks {
                crate::prop_assert!(*t > 0, "empty chunk scheduled");
            }
            Ok(())
        });
    }
}
