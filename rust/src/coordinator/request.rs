//! Request lifecycle: phases, SLOs, progress tracking.
//!
//! A request moves Encode -> Prefill -> Decode -> Done (text requests skip
//! Encode).  The *phase is a request attribute, not an instance attribute*
//! (paper §3.2 "stateless instance"), which is what lets any instance
//! serve any phase and pools flip roles with zero wait.

use crate::metrics::{PhaseBreakdown, RequestOutcome, Slo};
use crate::obs::SpanPhase;
use crate::workload::{RequestClass, RequestSpec};

pub type RequestId = u64;

/// Inference phase of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Multimodal image encoding (§3.3).
    Encode,
    /// Prompt prefill (possibly chunked, §3.2).
    Prefill,
    /// Autoregressive decode.
    Decode,
    Done,
    /// Dropped by fault handling / admission control.
    Failed,
}

/// A live request in the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub spec: RequestSpec,
    pub slo: Slo,
    pub phase: Phase,
    /// Prompt tokens already prefilled (chunked prefill progress).
    pub prefilled: u64,
    /// Output tokens generated so far.
    pub decoded: u64,
    /// Encode completed (multimodal only).
    pub encoded: bool,
    /// Timestamps (simulated seconds).
    pub first_token_s: Option<f64>,
    pub finish_s: Option<f64>,
    /// Phase-start timestamps (first submitted work per phase) — pure
    /// bookkeeping for the per-phase latency breakdown and the trace
    /// spans; never read by scheduling decisions.  Fault-recovery
    /// recompute resets them so the re-run restarts the attribution.
    pub encode_start_s: Option<f64>,
    pub prefill_start_s: Option<f64>,
    pub decode_start_s: Option<f64>,
    /// Prefix tokens satisfied from the global KV cache (skip prefill).
    pub prefix_hit_tokens: u64,
    /// Times this request was preempted (offline co-location).
    pub preemptions: u32,
    /// Times this request was migrated across instances.
    pub migrations: u32,
}

impl Request {
    pub fn new(id: RequestId, spec: RequestSpec, slo: Slo) -> Request {
        let phase = if spec.is_multimodal() { Phase::Encode } else { Phase::Prefill };
        Request {
            id,
            spec,
            slo,
            phase,
            prefilled: 0,
            decoded: 0,
            encoded: false,
            first_token_s: None,
            finish_s: None,
            encode_start_s: None,
            prefill_start_s: None,
            decode_start_s: None,
            prefix_hit_tokens: 0,
            preemptions: 0,
            migrations: 0,
        }
    }

    pub fn is_online(&self) -> bool {
        self.spec.class == RequestClass::Online
    }

    /// Prompt tokens still needing prefill.
    pub fn prefill_remaining(&self) -> u64 {
        self.spec.input_tokens.saturating_sub(self.prefilled.max(self.prefix_hit_tokens))
    }

    /// Total context length right now (for KV accounting).
    pub fn context_len(&self) -> u64 {
        self.prefilled.max(self.prefix_hit_tokens) + self.decoded
    }

    /// Output tokens still to generate.
    pub fn decode_remaining(&self) -> u64 {
        self.spec.output_tokens.saturating_sub(self.decoded)
    }

    /// Advance prefill by `tokens`; transitions to Decode when complete.
    /// Returns true if prefill just completed.
    pub fn advance_prefill(&mut self, tokens: u64, now_s: f64) -> bool {
        debug_assert!(matches!(self.phase, Phase::Prefill));
        self.prefilled = (self.prefilled.max(self.prefix_hit_tokens) + tokens)
            .min(self.spec.input_tokens);
        if self.prefill_remaining() == 0 {
            self.phase = Phase::Decode;
            // prefill emits the first output token
            self.decoded = self.decoded.max(1);
            if self.first_token_s.is_none() {
                self.first_token_s = Some(now_s);
            }
            if self.decode_remaining() == 0 {
                self.phase = Phase::Done;
                self.finish_s = Some(now_s);
            }
            true
        } else {
            false
        }
    }

    /// Record `n` decode tokens; transitions to Done when complete.
    /// Returns true if the request just finished.
    pub fn advance_decode(&mut self, n: u64, now_s: f64) -> bool {
        debug_assert!(matches!(self.phase, Phase::Decode));
        if self.first_token_s.is_none() {
            self.first_token_s = Some(now_s);
        }
        self.decoded = (self.decoded + n).min(self.spec.output_tokens);
        if self.decode_remaining() == 0 {
            self.phase = Phase::Done;
            self.finish_s = Some(now_s);
            true
        } else {
            false
        }
    }

    /// Mark encode complete; transitions to Prefill.
    pub fn finish_encode(&mut self) {
        debug_assert!(matches!(self.phase, Phase::Encode));
        self.encoded = true;
        self.phase = Phase::Prefill;
    }

    pub fn fail(&mut self, now_s: f64) {
        self.phase = Phase::Failed;
        self.finish_s = Some(now_s);
    }

    /// The lifecycle span currently open for this request, derived from
    /// the phase + the phase-start stamps (the trace layer closes it on
    /// failure/fault/drain).  `None` between prefill completion and the
    /// first decode submit — the handoff gap, traced as its own
    /// known-duration span.
    pub fn open_span(&self) -> Option<SpanPhase> {
        match self.phase {
            Phase::Decode if self.decode_start_s.is_some() => Some(SpanPhase::Decode),
            Phase::Decode => None,
            Phase::Prefill if self.prefill_start_s.is_some() => Some(SpanPhase::Prefill),
            Phase::Prefill => Some(SpanPhase::Queue),
            Phase::Encode if self.encode_start_s.is_some() => Some(SpanPhase::Encode),
            Phase::Encode => Some(SpanPhase::Queue),
            Phase::Done | Phase::Failed => None,
        }
    }

    /// Per-phase latency attribution from the recorded stamps.  Each
    /// component clamps non-negative (fault recovery can re-run prefill
    /// after the first token) and `queue_s` takes the residual, so the
    /// four parts never exceed the E2E span.
    fn phase_breakdown(&self, finish: f64) -> PhaseBreakdown {
        let e2e = (finish - self.spec.arrival_s).max(0.0);
        let prefill_s = match (self.prefill_start_s, self.first_token_s) {
            (Some(p0), Some(ft)) => (ft - p0).max(0.0),
            _ => 0.0,
        };
        let decode_s = self.decode_start_s.map_or(0.0, |d0| (finish - d0).max(0.0));
        let handoff_s = match (self.first_token_s, self.decode_start_s) {
            (Some(ft), Some(d0)) => (d0 - ft).max(0.0),
            _ => 0.0,
        };
        let attributed = prefill_s + handoff_s + decode_s;
        let (prefill_s, handoff_s, decode_s) = if attributed > e2e && attributed > 0.0 {
            // recovery overlap: scale the parts into the E2E budget
            let k = e2e / attributed;
            (prefill_s * k, handoff_s * k, decode_s * k)
        } else {
            (prefill_s, handoff_s, decode_s)
        };
        PhaseBreakdown {
            queue_s: (e2e - prefill_s - handoff_s - decode_s).max(0.0),
            prefill_s,
            handoff_s,
            decode_s,
        }
    }

    /// Completion record for the metrics layer.
    pub fn outcome(&self) -> Option<RequestOutcome> {
        let finish = self.finish_s?;
        Some(RequestOutcome {
            arrival_s: self.spec.arrival_s,
            first_token_s: self.first_token_s.unwrap_or(finish),
            finish_s: finish,
            input_tokens: self.spec.input_tokens,
            output_tokens: self.decoded,
            failed: matches!(self.phase, Phase::Failed),
            prefix_hit_tokens: self.prefix_hit_tokens,
            phases: self.phase_breakdown(finish),
            tier: self.spec.tier,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(input: u64, output: u64) -> Request {
        Request::new(1, RequestSpec::text(0.0, input, output), Slo::UNCONSTRAINED)
    }

    #[test]
    fn lifecycle_text() {
        let mut r = req(100, 3);
        assert_eq!(r.phase, Phase::Prefill);
        assert!(!r.advance_prefill(60, 1.0));
        assert_eq!(r.prefill_remaining(), 40);
        assert!(r.advance_prefill(40, 2.0));
        assert_eq!(r.phase, Phase::Decode);
        assert_eq!(r.first_token_s, Some(2.0));
        assert_eq!(r.decoded, 1);
        assert!(!r.advance_decode(1, 3.0));
        assert!(r.advance_decode(1, 4.0));
        assert_eq!(r.phase, Phase::Done);
        let o = r.outcome().unwrap();
        assert_eq!(o.output_tokens, 3);
        assert!((o.ttft() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn multimodal_starts_in_encode() {
        let mut spec = RequestSpec::text(0.0, 10, 5);
        spec.image_patches = 64;
        let mut r = Request::new(2, spec, Slo::UNCONSTRAINED);
        assert_eq!(r.phase, Phase::Encode);
        r.finish_encode();
        assert_eq!(r.phase, Phase::Prefill);
    }

    #[test]
    fn prefix_hit_reduces_prefill() {
        let mut r = req(100, 2);
        r.prefix_hit_tokens = 80;
        assert_eq!(r.prefill_remaining(), 20);
        assert!(r.advance_prefill(20, 1.0));
        assert_eq!(r.phase, Phase::Decode);
    }

    #[test]
    fn single_token_output_finishes_at_prefill() {
        let mut r = req(10, 1);
        assert!(r.advance_prefill(10, 1.0));
        assert_eq!(r.phase, Phase::Done);
        assert_eq!(r.finish_s, Some(1.0));
    }

    #[test]
    fn overshoot_is_clamped() {
        let mut r = req(10, 2);
        r.advance_prefill(1000, 1.0);
        assert_eq!(r.prefilled, 10);
        r.advance_decode(1000, 2.0);
        assert_eq!(r.decoded, 2);
    }

    #[test]
    fn failed_outcome_flagged() {
        let mut r = req(10, 2);
        r.fail(5.0);
        let o = r.outcome().unwrap();
        assert!(o.failed);
    }
}
