//! The request-lifecycle state machine (moved out of `sim::cluster`).
//!
//! Drives the coordinator/service policy code over an event queue:
//! request arrival → (encode) → dispatch → chunked prefill iterations →
//! KV handoff → batched decode iterations → completion, with dynamic PD
//! role switching, online/offline co-location, fault injection, and the
//! prefix cache all live.  Iteration execution — and therefore how time
//! advances — is delegated to the [`Executor`] through its two-phase
//! submit/complete contract.
//!
//! # Async pipeline (§4.2)
//!
//! Each instance owns a FIFO pipeline of up to
//! [`OrchestratorConfig::pipeline_depth`] in-flight iterations.  While
//! iteration N runs "on the device", the orchestrator plans iteration
//! N+1 against the *predicted* post-completion state (submitted prefill
//! chunks count as computed, every in-flight decode is assumed to emit
//! one token), so the host-side planning cost hides under device time.
//! Completions re-enter through `Ev::IterDone(instance, seq)` events and
//! reconcile against the live state — a look-ahead plan may carry a
//! request that already finished (the real pipeline bubble), which is
//! priced but advances nothing.  At depth 1 the look-ahead view is the
//! live state and the timeline charges `host + device` per iteration:
//! exactly the pre-async blocking behavior, event for event.

use std::collections::{BTreeMap, HashMap, VecDeque};

use crate::coordinator::orchestrator::{
    ColocationMode, DecodeWork, EncodeWork, Executor, InFlightSnapshot, IterationTicket,
    IterationWork, LoadReport, OrchestratorConfig, PrefillWork, RunResult, ServingMode,
};
use crate::coordinator::{
    plan_iteration, plan_role_switches, BatchConfig, ElasticPools, GlobalScheduler, InstanceId,
    InstanceState, InstanceView, Phase, Placement, PoolKind, Request, RequestId, RoleFlip,
};
use crate::metrics::{ServingReport, Slo};
use crate::obs::{InstantKind, SpanPhase, TraceHandle};
use crate::service::colocation::admit_offline_decodes;
use crate::service::fault::{plan_recovery, InterruptedRequest, RecoveryAction};
use crate::service::kvstore::{hash_chain, prefix_tokens, Tier, TieredCache, TransferEngine};
use crate::sim::clock::EventQueue;
use crate::workload::RequestSpec;

#[derive(Debug, Clone)]
enum Ev {
    Arrive(usize),
    /// Iteration completion: (instance, ticket seq).  The seq matches the
    /// completion to its pipeline slot, so completions whose pipeline was
    /// cleared by a fault are recognizably stale and dropped.
    IterDone(InstanceId, u64),
    KvReady(InstanceId),
    Monitor,
    Fault(usize),
    Recover(usize),
}

/// One iteration in flight on an instance (FIFO pipeline slot).
struct InFlight {
    /// Ticket seq (executor-assigned, never reused).
    seq: u64,
    work: IterationWork,
    /// Span this iteration occupies on the instance timeline (completion
    /// minus pipeline-ready time) — the monitor's TPOT attribution.  At
    /// depth 1 this is `host_s + device_s`; warm at depth ≥ 2 it is the
    /// device time alone (host hidden).
    duration: f64,
    /// Ticket still owed its `poll_complete` at the completion event
    /// (depth ≥ 2; depth 1 completes at submit).
    ticket: Option<IterationTicket>,
}

/// The shared serving orchestrator, generic over the execution backend.
pub struct Orchestrator<X: Executor> {
    cfg: OrchestratorConfig,
    executor: X,
    xfer: TransferEngine,
    queue: EventQueue<Ev>,
    instances: Vec<InstanceState>,
    pools: ElasticPools,
    scheduler: GlobalScheduler,
    /// Live (non-terminal) requests only: terminal entries are dropped
    /// at record time, so resident state tracks in-flight work — not
    /// total submissions — and a streaming replica can serve unbounded
    /// request counts in bounded memory.
    requests: HashMap<RequestId, Request>,
    /// Specs of requests not yet recorded, keyed by request id (the
    /// BTreeMap keeps [`Self::drain_in_flight`] deterministic).  Ids
    /// come from `submitted_total`, which never decreases.
    specs: BTreeMap<usize, RequestSpec>,
    /// Requests ever handed to this replica (terminal ones included).
    submitted_total: usize,
    /// Per-instance FIFO of in-flight iterations (≤ `pipeline_depth`).
    inflight: HashMap<InstanceId, VecDeque<InFlight>>,
    /// Per-instance host / device timeline frontiers: when the host is
    /// free to plan the next iteration and when the device finishes
    /// everything submitted so far.  Both reduce to "now" at depth 1.
    host_free: Vec<f64>,
    device_free: Vec<f64>,
    /// Per-instance pipeline-parallel entry frontier: when the device
    /// group's first pp stage can accept the next iteration's
    /// micro-batches — `device_free - ramp_s` of the newest submission
    /// (the pp drain tail overlaps the next iteration's fill).  Tracks
    /// `device_free` exactly while executors report `ramp_s == 0`, so
    /// unsharded timelines are bit-identical to the two-frontier model.
    stage_free: Vec<f64>,
    /// Where each request's prefill ran (decode placement preference).
    prefill_home: HashMap<RequestId, InstanceId>,
    prefix_cache: TieredCache,
    report: ServingReport,
    preemptions: u64,
    migrations: u64,
    recoveries: u64,
    prefix_hits: u64,
    prefix_hit_tokens: u64,
    admission_overcommit_tokens: u64,
    iterations: u64,
    truncated: bool,
    /// A monitor event is pending in the queue (so incremental `submit`
    /// can revive monitoring after the replica drains).
    monitor_live: bool,
    /// Lifecycle trace emission (off by default — every emission is one
    /// `Option` check and never touches simulation state).
    trace: TraceHandle,
}

impl<X: Executor> Orchestrator<X> {
    pub fn new(cfg: OrchestratorConfig, executor: X) -> Orchestrator<X> {
        let (n_p, n_d) = match cfg.mode {
            ServingMode::Colocated => (0, cfg.n_instances),
            ServingMode::Disaggregated { n_prefill, .. } => {
                let p = n_prefill.min(cfg.n_instances);
                (p, cfg.n_instances - p)
            }
        };
        let pools = ElasticPools::new(n_p, n_d, cfg.n_encode);
        let instances: Vec<InstanceState> = (0..cfg.n_instances + cfg.n_encode)
            .map(|id| InstanceState::new(id, executor.cost().clone(), cfg.batch))
            .collect();
        let scheduler = GlobalScheduler::new(cfg.dispatch);
        let mut prefix_cache = TieredCache::new(
            cfg.prefix_block_tokens,
            cfg.prefix_hbm_tokens,
            cfg.prefix_dram_tokens,
            cfg.prefix_ssd_tokens,
        );
        if cfg.prefix_token_granular {
            // token-granular replicas publish incremental summary deltas
            // instead of full snapshots, so residency churn must be
            // logged from the very first insert
            prefix_cache.enable_delta_tracking();
        }
        let n_total = instances.len();
        Orchestrator {
            executor,
            xfer: TransferEngine::default(),
            queue: EventQueue::new(),
            instances,
            pools,
            scheduler,
            requests: HashMap::new(),
            specs: BTreeMap::new(),
            submitted_total: 0,
            inflight: HashMap::new(),
            host_free: vec![0.0; n_total],
            device_free: vec![0.0; n_total],
            stage_free: vec![0.0; n_total],
            prefill_home: HashMap::new(),
            prefix_cache,
            report: ServingReport::new(),
            preemptions: 0,
            migrations: 0,
            recoveries: 0,
            prefix_hits: 0,
            prefix_hit_tokens: 0,
            admission_overcommit_tokens: 0,
            iterations: 0,
            truncated: false,
            monitor_live: false,
            trace: TraceHandle::off(),
            cfg,
        }
    }

    /// Install the trace handle (and hand a clone to the executor for
    /// its own policy events).  Call before `start`/`run`.
    pub fn set_trace(&mut self, trace: TraceHandle) {
        self.executor.set_trace(trace.clone());
        self.trace = trace;
    }

    pub fn executor(&self) -> &X {
        &self.executor
    }

    pub fn executor_mut(&mut self) -> &mut X {
        &mut self.executor
    }

    /// Run the workload to completion; returns metrics + counters and
    /// hands the executor back (real backends carry per-request results).
    pub fn run(mut self, workload: Vec<RequestSpec>) -> (RunResult, X) {
        self.start(workload);
        while self.step() {}
        self.finish()
    }

    /// Schedule a workload without running it (steppable entry point —
    /// the control plane interleaves several replicas' event queues).
    pub fn start(&mut self, workload: Vec<RequestSpec>) {
        self.start_at(workload, 0.0);
    }

    /// [`Self::start`] with the local clock pre-advanced to `now_s`.
    /// A replica spawned mid-run (autoscale-up) must align with fleet
    /// time first, or its initial monitor tick would fire "in the past"
    /// relative to every other replica's head event.
    pub fn start_at(&mut self, workload: Vec<RequestSpec>, now_s: f64) {
        self.queue.advance_to(now_s);
        self.specs = workload.into_iter().enumerate().collect();
        self.submitted_total = self.specs.len();
        for (&i, spec) in &self.specs {
            self.queue.schedule_at(spec.arrival_s, Ev::Arrive(i));
            self.executor.admitted(i as RequestId, spec);
        }
        for (t, inst) in self.cfg.faults.clone() {
            self.queue.schedule_at(t, Ev::Fault(inst));
        }
        self.queue.schedule_in(self.cfg.monitor_interval_s, Ev::Monitor);
        self.monitor_live = true;
    }

    /// Adopt a prefix chain whose KV was migrated here by the control
    /// plane's *planned* rebalancing (§3.4 proactive movement): the
    /// blocks land in DRAM per the consistency rule, so subsequent
    /// arrivals sharing the prefix hit this replica's local cache.
    /// No-op when the prefix cache is disabled.
    pub fn adopt_chain(&mut self, chain: &[u64]) {
        if self.cfg.prefix_cache && !chain.is_empty() {
            self.prefix_cache.insert_chain(chain, Tier::Dram);
        }
    }

    /// Inject one request after the fact (control-plane routing).  The
    /// arrival event fires no earlier than `earliest_s` — the fleet
    /// time of the routing decision plus any staging delay — so a
    /// replica whose local clock lags fleet time (it drained and froze)
    /// cannot execute re-dispatched work "in the past".  The spec's own
    /// `arrival_s` is preserved for metrics, so failover latency lands
    /// in the request's E2E.  Monitoring is revived if the replica had
    /// drained.
    pub fn submit_at(&mut self, spec: RequestSpec, earliest_s: f64) {
        // ids come from the monotone submission counter, never from the
        // live map's size — terminal entries are removed, and a reused
        // id would collide with an in-flight request
        let i = self.submitted_total;
        self.submitted_total += 1;
        self.specs.insert(i, spec);
        self.executor.admitted(i as RequestId, &spec);
        self.queue.schedule_at(spec.arrival_s.max(earliest_s), Ev::Arrive(i));
        if !self.monitor_live {
            self.queue.schedule_in(self.cfg.monitor_interval_s, Ev::Monitor);
            self.monitor_live = true;
        }
    }

    /// [`Self::submit_at`] with no lower bound beyond the spec's own
    /// arrival time (clamped to the local clock).
    pub fn submit(&mut self, spec: RequestSpec) {
        self.submit_at(spec, spec.arrival_s);
    }

    /// Process the next event.  Returns false once the replica is
    /// drained (every submitted request recorded) or the event cap hit —
    /// `run` loops on this; the control plane instead keeps polling
    /// [`Self::next_event_time`] because `submit` can add work back.
    pub fn step(&mut self) -> bool {
        if self.truncated {
            return false;
        }
        let Some((_, ev)) = self.queue.next() else {
            return false;
        };
        match ev {
            Ev::Arrive(i) => self.on_arrive(i),
            Ev::IterDone(id, seq) => self.on_iter_done(id, seq),
            Ev::KvReady(id) => self.kick(id),
            Ev::Monitor => self.on_monitor(),
            Ev::Fault(id) => self.on_fault(id),
            Ev::Recover(id) => self.on_recover(id),
        }
        if self.queue.processed() > self.cfg.max_events {
            // cap to guarantee termination on pathological configs
            self.truncated = true;
            return false;
        }
        // drained when only the monitor tick remains AND no iteration is
        // still in flight (a trailing look-ahead bubble after the last
        // completion must still be processed so its ticket gets polled)
        !(self.all_done()
            && self.queue.len() <= 1
            && self.inflight.values().all(|q| q.is_empty()))
    }

    /// Finalize: metrics + counters, handing the executor back (real
    /// backends carry per-request results).
    pub fn finish(self) -> (RunResult, X) {
        let result = RunResult {
            role_flips: self.pools.flips,
            preemptions: self.preemptions,
            migrations: self.migrations,
            recoveries: self.recoveries,
            prefix_hits: self.prefix_hits,
            prefix_hit_tokens: self.prefix_hit_tokens,
            admission_overcommit_tokens: self.admission_overcommit_tokens,
            iterations: self.iterations,
            events: self.queue.processed(),
            truncated: self.truncated,
            per_instance: self
                .instances
                .iter()
                .map(|i| (i.monitor.iterations, i.monitor.tokens_generated))
                .collect(),
            report: self.report,
        };
        (result, self.executor)
    }

    /// Local virtual time of this replica.
    pub fn now(&self) -> f64 {
        self.queue.now()
    }

    /// Timestamp of this replica's next pending event.
    pub fn next_event_time(&self) -> Option<f64> {
        self.queue.peek_time()
    }

    /// The replica hit its event cap and wedged (control plane treats
    /// this as a failure and re-dispatches its work).
    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// Requests with a recorded outcome (completed or failed) so far.
    pub fn n_recorded(&self) -> usize {
        self.report.n_requests()
    }

    /// Aggregate load snapshot published to the control-plane registry
    /// on each heartbeat lease renewal (§3.4 load-info synchronization).
    pub fn load_report(&self) -> LoadReport {
        let mut rep = LoadReport::default();
        rep.shard = self.executor.cost().features.shard;
        for id in 0..self.instances.len() {
            let v = self.view(id);
            rep.queued_prefill_tokens += v.queued_prefill_tokens;
            rep.running_tokens += v.running_tokens;
            rep.kv_used += v.kv_used;
            rep.kv_capacity += v.kv_capacity;
            rep.n_running += v.n_running;
            rep.n_queued += v.n_queued;
        }
        let (mut online, mut in_flight) = (0u64, 0u64);
        for r in self.requests.values() {
            if !matches!(r.phase, Phase::Done | Phase::Failed) {
                in_flight += 1;
                if r.is_online() {
                    online += 1;
                }
            }
        }
        rep.online_fraction =
            if in_flight == 0 { 0.0 } else { online as f64 / in_flight as f64 };
        rep
    }

    /// Prefix-cache chain summary published to the control plane's
    /// global index on each heartbeat (§3.4 aggregated load/offload
    /// events).
    pub fn cache_summary(&self) -> Vec<(u64, Tier)> {
        self.prefix_cache.summary()
    }

    /// Drain the residency mutations logged since the last heartbeat
    /// (token-granular fleets publish these instead of a full
    /// [`Self::cache_summary`] snapshot — satellite fix for the
    /// per-heartbeat full republish).  Empty unless delta tracking is on.
    pub fn cache_summary_delta(&mut self) -> Vec<(u64, Option<Tier>)> {
        self.prefix_cache.take_summary_delta()
    }

    /// Turn on residency delta logging (idempotent; the control plane
    /// calls this on every replica of a token-granular fleet, including
    /// ones whose [`OrchestratorConfig::prefix_token_granular`] was not
    /// set by their factory).
    pub fn enable_cache_delta_tracking(&mut self) {
        self.prefix_cache.enable_delta_tracking();
    }

    /// Switch the serving report to streaming (sketch-only) mode:
    /// outcomes are folded into fixed-size histogram sketches instead of
    /// being retained per-request, so report memory is O(1) in request
    /// count.  Aggregates (counts, throughput, horizon, per-tier
    /// goodput) are unchanged; only the per-outcome summaries go away.
    /// Call before any request is recorded.
    pub fn enable_streaming_report(&mut self) {
        self.report.set_streaming();
    }

    /// Snapshot and forget every request that has not completed:
    /// pending arrivals, queued prefills, running decodes.  Called by
    /// the control plane when this replica's lease expires, so the
    /// survivors can re-run them (§3.5 re-dispatch).  The drained
    /// requests never reach this replica's report.
    pub fn drain_in_flight(&mut self) -> Vec<InFlightSnapshot> {
        let now = self.queue.now();
        let mut out = Vec::new();
        for (&idx, spec) in &self.specs {
            let id = idx as RequestId;
            match self.requests.get(&id) {
                // terminal entries are removed at record time, so this
                // arm only guards a not-yet-cleaned state (none today)
                Some(r) if matches!(r.phase, Phase::Done | Phase::Failed) => {}
                Some(r) => {
                    // the snapshot leaves this replica: close its span so
                    // the re-dispatched copy (a fresh request id on the
                    // survivor) starts a clean lifecycle
                    if let Some(p) = r.open_span() {
                        self.trace.end(now, None, Some(id), p);
                    }
                    out.push(InFlightSnapshot {
                        spec: *spec,
                        context_tokens: r.context_len(),
                        decoding: matches!(r.phase, Phase::Decode),
                    });
                }
                // arrival event still pending: nothing computed yet
                None => out.push(InFlightSnapshot {
                    spec: *spec,
                    context_tokens: 0,
                    decoding: false,
                }),
            }
        }
        out
    }

    fn all_done(&self) -> bool {
        self.report.n_requests() >= self.submitted_total
    }

    fn view(&self, id: InstanceId) -> InstanceView {
        let inst = &self.instances[id];
        let queued_prefill_tokens: u64 = inst
            .prefill_queue
            .iter()
            .filter_map(|r| self.requests.get(r))
            .map(|r| r.prefill_remaining())
            .sum();
        let running_tokens: u64 = inst
            .running
            .iter()
            .filter_map(|r| self.requests.get(r))
            .map(|r| r.context_len())
            .sum();
        InstanceView {
            id,
            queued_prefill_tokens,
            running_tokens,
            n_running: inst.running.len(),
            n_queued: inst.prefill_queue.len(),
            kv_used: inst.kv_tokens,
            kv_capacity: inst.batch.kv_capacity_tokens,
            failed: inst.failed,
            ema_token_interval: inst.monitor.ema_token_interval,
            ema_ttft: inst.monitor.ema_ttft,
        }
    }

    fn views(&self, ids: &[InstanceId]) -> Vec<InstanceView> {
        ids.iter().map(|&i| self.view(i)).collect()
    }

    fn alive(&self, ids: Vec<InstanceId>) -> Vec<InstanceId> {
        ids.into_iter().filter(|&i| !self.instances[i].failed).collect()
    }

    /// Fail a request that could not be placed anywhere.
    fn fail_request(&mut self, rid: RequestId) {
        let now = self.queue.now();
        let r = self.requests.get_mut(&rid).unwrap();
        let open = r.open_span();
        r.fail(now);
        if let Some(p) = open {
            self.trace.end(now, None, Some(rid), p);
        }
        self.trace.instant(now, None, Some(rid), InstantKind::Failure);
        if let Some(o) = r.outcome() {
            self.report.record(o);
        }
        self.executor.finished(rid, now);
        // terminal cleanup, mirroring complete_request
        self.prefill_home.remove(&rid);
        self.requests.remove(&rid);
        self.specs.remove(&(rid as usize));
    }

    // --- arrival -------------------------------------------------------

    fn on_arrive(&mut self, idx: usize) {
        let spec = self.specs[&idx];
        let id = idx as RequestId;
        let mut req = Request::new(id, spec, self.cfg.slo);

        // prefix cache lookup (§3.4): shared system prompts skip prefill
        if self.cfg.prefix_cache && spec.shared_prefix > 0 {
            let tokens = prefix_tokens(spec.prefix_group, spec.shared_prefix);
            let hit = if self.cfg.prefix_token_granular {
                // token-granular match: credit the exact matched token
                // count, including a sub-block tail past the last full
                // resident block
                let (matched, _) = self.prefix_cache.match_prefix_tokens(&tokens);
                self.prefix_cache.insert_tokens(&tokens, Tier::Dram);
                matched.min(spec.shared_prefix).min(spec.input_tokens.saturating_sub(1))
            } else {
                let chain = hash_chain(&tokens, self.prefix_cache.block_tokens as usize);
                let (blocks, _) = self.prefix_cache.match_prefix(&chain);
                let hit = (blocks as u64 * self.prefix_cache.block_tokens)
                    .min(spec.shared_prefix)
                    .min(spec.input_tokens.saturating_sub(1));
                self.prefix_cache.insert_chain(&chain, Tier::Dram);
                hit
            };
            if hit > 0 {
                req.prefix_hit_tokens = hit;
                self.prefix_hits += 1;
                self.prefix_hit_tokens += hit;
            }
        }

        let multimodal = spec.is_multimodal();
        self.requests.insert(id, req);
        let now = self.queue.now();
        self.trace.instant(now, None, Some(id), InstantKind::Arrival);
        self.trace.begin(now, None, Some(id), SpanPhase::Queue);
        if multimodal && self.cfg.epd.is_some() {
            self.route_encode(id);
        } else {
            if multimodal {
                // no EPD support: encode fused into prefill on one instance
                self.requests.get_mut(&id).unwrap().finish_encode();
            }
            self.route_prefill(id);
        }
    }

    fn route_encode(&mut self, id: RequestId) {
        use crate::service::epd::placement;
        let strategy = self.cfg.epd.unwrap();
        let place = placement(strategy);
        let pool_ids = match place.encode_pool {
            0 => self.alive(self.pools.prefill_capable()),
            1 => self.alive(self.pools.decode_capable()),
            _ => self.alive(self.pools.encode_capable()),
        };
        let pool_ids = if pool_ids.is_empty() {
            self.alive((0..self.instances.len()).collect())
        } else {
            pool_ids
        };
        let target = pool_ids
            .into_iter()
            .min_by_key(|&i| self.instances[i].encode_queue.len())
            .expect("no instance for encode");
        self.instances[target].encode_queue.push_back(id);
        self.kick(target);
    }

    fn route_prefill(&mut self, id: RequestId) {
        let req = &self.requests[&id];
        let input = req.prefill_remaining();
        let is_online = req.is_online();

        let (primary_ids, fallback_ids) = match self.cfg.mode {
            ServingMode::Colocated => {
                (self.alive((0..self.cfg.n_instances).collect()), Vec::new())
            }
            ServingMode::Disaggregated { .. } => (
                self.alive(self.pools.of_kind(PoolKind::Prefill)),
                self.alive(self.pools.of_kind(PoolKind::DecodeToPrefill)),
            ),
        };
        let primary = self.views(&primary_ids);
        let fallback = self.views(&fallback_ids);
        let slo = if is_online { self.cfg.slo } else { Slo::UNCONSTRAINED };
        let placement = self.scheduler.place_prefill(
            &primary,
            &fallback,
            self.executor.cost(),
            input,
            &slo,
        );
        let target = match placement {
            Placement::Instance(i) => i,
            Placement::NeedFlip => {
                // dynamic PD: convert the lightest decode instance
                let flipped =
                    if let ServingMode::Disaggregated { dynamic: true, .. } = self.cfg.mode {
                        let candidates = self.alive(self.pools.decode_capable());
                        candidates
                            .into_iter()
                            .min_by_key(|&i| self.view(i).running_tokens)
                            .filter(|&i| self.pools.flip_to_prefill(i, 2))
                    } else {
                        None
                    };
                match flipped {
                    Some(i) => i,
                    None => {
                        // no flip possible: least-loaded anywhere
                        match primary
                            .iter()
                            .chain(fallback.iter())
                            .min_by_key(|v| v.queued_prefill_tokens)
                        {
                            Some(v) => v.id,
                            None => {
                                self.fail_request(id);
                                return;
                            }
                        }
                    }
                }
            }
        };
        self.instances[target].prefill_queue.push_back(id);
        self.kick(target);
    }

    // --- iteration execution -------------------------------------------

    /// Number of iterations in flight on `id`.
    fn inflight_len(&self, id: InstanceId) -> usize {
        self.inflight.get(&id).map_or(0, |q| q.len())
    }

    /// Fill this instance's pipeline: plan and submit iterations until
    /// the configured depth is reached or nothing more can be planned.
    /// At depth 1 this submits at most one iteration after the previous
    /// one completed — the blocking contract.
    fn kick(&mut self, id: InstanceId) {
        while self.submit_next(id) {}
    }

    /// Plan one iteration against the look-ahead view and submit it.
    /// Returns true when an iteration was submitted.
    fn submit_next(&mut self, id: InstanceId) -> bool {
        if self.inflight_len(id) >= self.cfg.pipeline_depth.max(1) {
            return false;
        }
        let inst = &self.instances[id];
        if inst.busy || inst.failed || !inst.has_work() {
            return false;
        }
        let pool = self.pools.kind(id);
        let colocated = matches!(self.cfg.mode, ServingMode::Colocated);

        let serves_prefill = colocated || pool.serves_prefill();
        // stateless instances (§3.2): pool membership steers NEW work, but
        // an instance always drains what it already holds (e.g. offline
        // decodes placed on latency-relaxed instances under co-location)
        let serves_decode = colocated || pool.serves_decode() || !inst.running.is_empty();
        let serves_encode = pool.serves_encode() || self.cfg.epd.is_some() || colocated;

        // Look-ahead view (§4.2 async scheduling): with iterations in
        // flight, plan the next one against the predicted post-completion
        // request states — submitted prefill chunks count as computed,
        // every in-flight decode is assumed to emit one token (actual
        // emission is never lower), finished encodes move to prefill.
        // With nothing in flight (always the case at depth 1) the view is
        // exactly the live state.
        let mut adj: HashMap<RequestId, Request> = HashMap::new();
        if let Some(q) = self.inflight.get(&id) {
            for fl in q {
                for d in &fl.work.decodes {
                    let Some(base) = self.requests.get(&d.req) else { continue };
                    let r = adj.entry(d.req).or_insert_with(|| base.clone());
                    if matches!(r.phase, Phase::Decode) {
                        r.advance_decode(1, 0.0);
                    }
                }
                for p in &fl.work.prefills {
                    let Some(base) = self.requests.get(&p.req) else { continue };
                    let r = adj.entry(p.req).or_insert_with(|| base.clone());
                    if matches!(r.phase, Phase::Prefill) {
                        r.advance_prefill(p.tokens, 0.0);
                    }
                }
                for e in &fl.work.encodes {
                    let Some(base) = self.requests.get(&e.req) else { continue };
                    let r = adj.entry(e.req).or_insert_with(|| base.clone());
                    if matches!(r.phase, Phase::Encode) {
                        r.finish_encode();
                    }
                }
            }
        }
        /// Predicted view of a request: the look-ahead clone if one
        /// exists, the live request otherwise.
        fn look<'a>(
            adj: &'a HashMap<RequestId, Request>,
            live: &'a HashMap<RequestId, Request>,
            rid: &RequestId,
        ) -> Option<&'a Request> {
            adj.get(rid).or_else(|| live.get(rid))
        }

        // phase filters drop requests whose in-flight work already moves
        // them past a phase (predicted-complete decodes, prefills mid
        // KV-handoff, finished encodes) — no-ops on the live view
        let running: Vec<&Request> = if serves_decode {
            inst.running
                .iter()
                .filter_map(|r| look(&adj, &self.requests, r))
                .filter(|r| matches!(r.phase, Phase::Decode))
                .collect()
        } else {
            Vec::new()
        };
        let queued: Vec<&Request> = if serves_prefill {
            inst.prefill_queue
                .iter()
                .filter_map(|r| look(&adj, &self.requests, r))
                .filter(|r| matches!(r.phase, Phase::Prefill))
                .collect()
        } else {
            Vec::new()
        };
        let encodes: Vec<&Request> = if serves_encode {
            inst.encode_queue
                .iter()
                .filter_map(|r| look(&adj, &self.requests, r))
                .filter(|r| matches!(r.phase, Phase::Encode))
                .collect()
        } else {
            Vec::new()
        };
        if running.is_empty() && queued.is_empty() && encodes.is_empty() {
            return false;
        }

        // online-priority co-location: offline prefill waits while any
        // online request is queued (dispatch-time priority, no runtime
        // admission control — the Fig 23 middle policy)
        let queued: Vec<&Request> =
            if let Some((ColocationMode::OnlinePriority, _)) = self.cfg.colocation {
                let any_online = queued.iter().any(|r| r.is_online());
                if any_online {
                    queued.into_iter().filter(|r| r.is_online()).collect()
                } else {
                    queued
                }
            } else {
                queued
            };

        // Slot admission stays pessimistic under look-ahead: a request
        // predicted past its current phase still occupies a physical
        // batch slot until its completion event actually frees it, and a
        // mid-KV-handoff prefill will claim a slot the moment it lands.
        // Both are invisible to the filtered views, so their count comes
        // off `max_seqs` instead (zero at depth 1: views == live state).
        let handoff = if serves_prefill {
            inst.prefill_queue
                .iter()
                .filter(|r| adj.get(r).is_some_and(|q| !matches!(q.phase, Phase::Prefill)))
                .count()
        } else {
            0
        };
        let hidden_slots = inst.running.len().saturating_sub(running.len()) + handoff;
        let batch = BatchConfig {
            max_seqs: inst.batch.max_seqs.saturating_sub(hidden_slots),
            ..inst.batch
        };
        let mut plan = plan_iteration(&running, &queued, &encodes, &batch);

        // co-location admission control: cap offline decodes so the step
        // stays within the online TPOT budget (§3.1 Solution 1)
        if let Some((ColocationMode::XllmOoc, coloc)) = &self.cfg.colocation {
            let online: Vec<RequestId> = plan
                .decode_ids
                .iter()
                .copied()
                .filter(|r| look(&adj, &self.requests, r).is_some_and(|q| q.is_online()))
                .collect();
            let offline: Vec<RequestId> = plan
                .decode_ids
                .iter()
                .copied()
                .filter(|r| look(&adj, &self.requests, r).is_some_and(|q| !q.is_online()))
                .collect();
            if !offline.is_empty() {
                let online_kv: u64 = online
                    .iter()
                    .map(|r| look(&adj, &self.requests, r).map_or(0, |q| q.context_len()))
                    .sum();
                let mean_ctx = (offline
                    .iter()
                    .map(|r| look(&adj, &self.requests, r).map_or(0, |q| q.context_len()))
                    .sum::<u64>()
                    / offline.len() as u64)
                    .max(1);
                let admit = admit_offline_decodes(
                    self.executor.cost(),
                    online.len().max(1) as u64,
                    online_kv,
                    offline.len() as u64,
                    mean_ctx,
                    coloc,
                ) as usize;
                if admit < offline.len() {
                    self.preemptions += (offline.len() - admit) as u64;
                    let t = self.queue.now();
                    for rid in &offline[admit..] {
                        self.trace.instant(t, Some(id), Some(*rid), InstantKind::Preemption);
                    }
                    let keep: Vec<RequestId> = offline.iter().copied().take(admit).collect();
                    plan.decode_ids = online.into_iter().chain(keep).collect();
                }
            }
        }
        // admission-overcommit accounting: prefill tokens admitted this
        // plan beyond the instance's free KV after the decode-growth
        // reserve (zero by construction under token-exact admission)
        self.admission_overcommit_tokens += plan.overcommit_tokens;
        self.preemptions += plan.preempted.len() as u64;
        if !plan.preempted.is_empty() {
            let t = self.queue.now();
            for rid in &plan.preempted {
                self.trace.instant(t, Some(id), Some(*rid), InstantKind::Preemption);
            }
        }

        if plan.is_empty() {
            return false;
        }

        // hand the planned work to the executor; virtual time advances by
        // whatever it reports (modelled cost or measured wall time)
        let work = IterationWork {
            decodes: plan
                .decode_ids
                .iter()
                .map(|r| DecodeWork {
                    req: *r,
                    context_tokens: look(&adj, &self.requests, r).map_or(0, |q| q.context_len()),
                })
                .collect(),
            prefills: plan
                .prefill_chunks
                .iter()
                .map(|&(r, tokens, ctx)| PrefillWork { req: r, tokens, context_tokens: ctx })
                .collect(),
            encodes: plan
                .encode_ids
                .iter()
                .map(|r| EncodeWork {
                    req: *r,
                    image_patches: look(&adj, &self.requests, r)
                        .map_or(0, |q| q.spec.image_patches),
                })
                .collect(),
        };
        let now = self.queue.now();
        self.note_phase_starts(id, now, &work);
        let ticket = self.executor.submit_iteration(id, now, &work);
        let (outcome, pending) = if self.cfg.pipeline_depth.max(1) == 1 {
            // depth 1 recovers the blocking contract: complete in-line
            (self.executor.poll_complete(ticket), None)
        } else {
            (ticket.est, Some(ticket))
        };
        // a zero/negative duration for non-empty work means the cost
        // model or backend is broken; surfacing it here beats the old
        // clamp-and-forget (`.max(1e-6)`) that silently rewrote it
        debug_assert!(
            outcome.total_s() > 0.0,
            "executor returned non-positive duration {} s for non-empty work on instance {id}",
            outcome.total_s()
        );

        // pipeline timeline: host planning runs serially per instance and
        // the device starts an iteration once both the host work and the
        // previous iteration are done.  At depth 1 both frontiers are in
        // the past, so this reduces exactly to the blocking
        // `now + host + device`.  Second pipelining axis (pp): a sharded
        // executor reports `ramp_s > 0` — its pp drain tail — so the
        // next iteration may enter the device group at `stage_free`
        // (first stage idle) while completions stay clamped to
        // `device_free` (the group is only fully done then).  With
        // `ramp_s == 0` the stage frontier tracks the device frontier
        // exactly and the timeline is bit-identical to the two-frontier
        // model.
        let host_done = now.max(self.host_free[id]) + outcome.host_s;
        let ready = now.max(self.device_free[id]);
        let start = host_done.max(self.stage_free[id]);
        let done = (start + outcome.device_s).max(self.device_free[id]);
        self.host_free[id] = host_done;
        self.stage_free[id] = done - outcome.ramp_s;
        self.device_free[id] = done;
        // instance-utilization track: one span per device iteration
        self.trace.complete(
            done - outcome.device_s,
            Some(id),
            None,
            SpanPhase::Iteration,
            outcome.device_s,
        );
        self.inflight.entry(id).or_default().push_back(InFlight {
            seq: ticket.seq,
            work,
            duration: done - ready,
            ticket: pending,
        });
        self.queue.schedule_at(done, Ev::IterDone(id, ticket.seq));
        true
    }

    /// Stamp first-submit phase starts on the live requests and emit the
    /// matching span transitions.  The timestamp writes are unconditional
    /// pure bookkeeping — they feed the per-phase latency breakdown and
    /// are never read by a scheduling decision — so trace-on and
    /// trace-off runs stay bit-identical.
    fn note_phase_starts(&mut self, id: InstanceId, now: f64, work: &IterationWork) {
        for e in &work.encodes {
            if let Some(r) = self.requests.get_mut(&e.req) {
                if matches!(r.phase, Phase::Encode) && r.encode_start_s.is_none() {
                    r.encode_start_s = Some(now);
                    self.trace.end(now, Some(id), Some(e.req), SpanPhase::Queue);
                    self.trace.begin(now, Some(id), Some(e.req), SpanPhase::Encode);
                }
            }
        }
        for p in &work.prefills {
            if let Some(r) = self.requests.get_mut(&p.req) {
                if matches!(r.phase, Phase::Prefill) && r.prefill_start_s.is_none() {
                    r.prefill_start_s = Some(now);
                    self.trace.end(now, Some(id), Some(p.req), SpanPhase::Queue);
                    self.trace.begin(now, Some(id), Some(p.req), SpanPhase::Prefill);
                }
            }
        }
        for d in &work.decodes {
            if let Some(r) = self.requests.get_mut(&d.req) {
                if matches!(r.phase, Phase::Decode) && r.decode_start_s.is_none() {
                    r.decode_start_s = Some(now);
                    self.trace.begin(now, Some(id), Some(d.req), SpanPhase::Decode);
                }
            }
        }
    }

    fn on_iter_done(&mut self, id: InstanceId, seq: u64) {
        let now = self.queue.now();
        let fl = match self.inflight.get_mut(&id) {
            Some(q) if q.front().map(|f| f.seq) == Some(seq) => q.pop_front().unwrap(),
            // stale completion: the pipeline was cleared by a fault and
            // this event belongs to the pre-fault generation
            _ => return,
        };
        let mut duration = fl.duration;
        if let Some(t) = fl.ticket {
            // depth ≥ 2: the ticket completes here, at the event that
            // re-enters the state machine.  Sim executors resolve to the
            // submit-time estimate exactly (virtual time stays exact and
            // `duration` keeps its pipeline-aware span); a real backend
            // blocks until its worker thread finishes and its measured
            // span replaces the estimate for the monitor's attribution —
            // the event timeline itself stays estimate-ordered.
            let measured = self.executor.poll_complete(t);
            if measured != t.est {
                duration = measured.total_s();
            }
        }
        if self.instances[id].failed {
            return; // fault handler already migrated the work
        }
        // NOTE: busy acts as a settle latch until bookkeeping completes,
        // so re-entrant kick() calls (e.g. from place_decode_for back
        // onto this instance) cannot plan against a half-applied state.
        self.instances[id].busy = true;
        self.iterations += 1;

        // encodes complete
        for e in &fl.work.encodes {
            let rid = e.req;
            let advanced = match self.requests.get_mut(&rid) {
                Some(r) if matches!(r.phase, Phase::Encode) => {
                    r.finish_encode();
                    true
                }
                _ => false, // look-ahead duplicate or failed request
            };
            if advanced {
                self.instances[id].encode_queue.retain(|x| *x != rid);
                self.trace.end(now, Some(id), Some(rid), SpanPhase::Encode);
                self.trace.begin(now, Some(id), Some(rid), SpanPhase::Queue);
                self.route_prefill(rid);
            }
        }

        // prefill chunks advance
        for p in &fl.work.prefills {
            let rid = p.req;
            let done = {
                let r = match self.requests.get_mut(&rid) {
                    Some(r) => r,
                    None => continue,
                };
                // a look-ahead plan may carry a chunk for a request that
                // failed or moved on in the meantime (depth ≥ 2 only)
                if !matches!(r.phase, Phase::Prefill) {
                    continue;
                }
                self.instances[id].kv_tokens += p.tokens;
                r.advance_prefill(p.tokens, now)
            };
            if done {
                let (finished, ttft, ctx, input, ft) = {
                    let r = &self.requests[&rid];
                    (
                        r.phase == Phase::Done,
                        r.first_token_s.unwrap_or(now) - r.spec.arrival_s,
                        r.context_len(),
                        r.spec.input_tokens,
                        r.first_token_s,
                    )
                };
                self.trace.end(now, Some(id), Some(rid), SpanPhase::Prefill);
                if ft == Some(now) {
                    // just stamped (not a fault-recovery re-run)
                    self.trace.instant(now, Some(id), Some(rid), InstantKind::FirstToken);
                }
                self.instances[id].prefill_queue.retain(|x| *x != rid);
                self.instances[id].monitor.observe_ttft(ttft);
                // feed the TTFT predictor (online factor learning)
                self.scheduler.predictor.observe(self.executor.cost(), 0, input, ttft.max(1e-6));
                if finished {
                    self.instances[id].kv_tokens =
                        self.instances[id].kv_tokens.saturating_sub(ctx);
                    self.complete_request(rid);
                } else {
                    self.prefill_home.insert(rid, id);
                    self.place_decode_for(rid, id, ctx);
                }
            }
        }

        // decodes advance
        let iter_dur = duration;
        let mut finished: Vec<RequestId> = Vec::new();
        for d in &fl.work.decodes {
            let rid = d.req;
            // one emission draw per planned decode, in plan order — the
            // draw happens even for a look-ahead bubble (the device ran
            // the sequence), preserving the RNG stream
            let tokens = self.executor.decode_emission(id, rid);
            let done = {
                let r = match self.requests.get_mut(&rid) {
                    Some(r) => r,
                    None => continue,
                };
                // a look-ahead plan (depth ≥ 2) may still carry a request
                // that completed in the previous iteration — the real
                // async-scheduling pipeline bubble: priced into the step,
                // advances nothing
                if !matches!(r.phase, Phase::Decode) {
                    continue;
                }
                let emitted = tokens.min(r.decode_remaining());
                self.instances[id].kv_tokens += emitted;
                r.advance_decode(tokens, now)
            };
            let per_token = iter_dur / tokens as f64;
            self.instances[id].monitor.observe_token_interval(per_token);
            self.instances[id].monitor.observe_iteration(tokens);
            if done {
                finished.push(rid);
            }
        }
        for rid in finished {
            let ctx = self.requests[&rid].context_len();
            self.instances[id].running.retain(|x| *x != rid);
            self.instances[id].kv_tokens =
                self.instances[id].kv_tokens.saturating_sub(ctx);
            self.complete_request(rid);
        }

        self.instances[id].busy = false;
        // invariant sweep at the iteration boundary: the prefix cache's
        // tier occupancy and the backend's own bookkeeping (e.g. xTensor
        // pages) must be consistent after every completed iteration
        #[cfg(debug_assertions)]
        {
            if let Err(e) = self.prefix_cache.check_invariants() {
                panic!("prefix-cache invariant violated after iteration {}: {e}", self.iterations);
            }
            if let Err(e) = self.executor.debug_check() {
                panic!("executor invariant violated after iteration {}: {e}", self.iterations);
            }
        }
        // layer-2 reactive workload migration (§4.4.3): only when the
        // pipeline is fully drained is this instance's running set in no
        // executing plan, so whole sequences can move to under-loaded
        // peers safely (always true at depth 1 at this point).  An
        // overloaded instance with iterations still in flight quiesces
        // instead of refilling, so the pipeline drains within `depth`
        // completions and the next boundary can migrate — without this,
        // depth ≥ 2 would never hit a drained boundary under sustained
        // load and layer-2 balancing would silently stop firing.
        if self.executor.cost().features.dp_balance {
            if self.inflight_len(id) == 0 {
                self.rebalance_from(id);
            } else if self.rebalance_would_migrate(id) {
                return; // quiesce: no refill, drain toward a boundary
            }
        }
        self.kick(id);
    }

    /// Rebalance tolerances (paper §4.4.3 layer 2): an instance is
    /// overloaded above `HI` × the peer-mean decode load; a target must
    /// sit below `LO` × mean to receive migrated sequences.
    const REBALANCE_TOLERANCE_HI: f64 = 1.25;
    const REBALANCE_TOLERANCE_LO: f64 = 0.80;
    const REBALANCE_MAX_MOVES: usize = 4;

    /// Decode-capable peers of `id` for layer-2 balancing (includes
    /// `id`); empty when balancing cannot apply.
    fn rebalance_peers(&self, id: InstanceId) -> Vec<InstanceId> {
        let colocated = matches!(self.cfg.mode, ServingMode::Colocated);
        let peers = if colocated {
            self.alive((0..self.cfg.n_instances).collect())
        } else {
            self.alive(self.pools.decode_capable())
        };
        if peers.len() < 2 || !peers.contains(&id) {
            return Vec::new();
        }
        peers
    }

    /// Context tokens of `i`'s running decode set (the layer-2 load
    /// metric).
    fn decode_load(&self, i: InstanceId) -> u64 {
        self.instances[i]
            .running
            .iter()
            .filter_map(|r| self.requests.get(r))
            .map(|r| r.context_len())
            .sum()
    }

    /// Would [`Self::rebalance_from`] move work off `id` right now?
    /// True only when `id` exceeds the peer mean by the HI tolerance
    /// AND some peer sits below the LO tolerance to receive it — the
    /// depth ≥ 2 quiesce trigger (quiescing for an overload no peer can
    /// absorb would serialize the pipeline for nothing).
    fn rebalance_would_migrate(&self, id: InstanceId) -> bool {
        let peers = self.rebalance_peers(id);
        if peers.is_empty() {
            return false;
        }
        let mine = self.decode_load(id);
        let total: u64 = peers.iter().map(|&p| self.decode_load(p)).sum();
        let mean = total as f64 / peers.len() as f64;
        mean > 0.0
            && (mine as f64) >= mean * Self::REBALANCE_TOLERANCE_HI
            && peers.iter().any(|&p| {
                p != id && (self.decode_load(p) as f64) < mean * Self::REBALANCE_TOLERANCE_LO
            })
    }

    /// Reactive inter-instance decode migration (paper §4.4.3 layer 2).
    ///
    /// If this instance's decode token load exceeds the cluster mean by
    /// more than the tolerance and a peer sits well below it, migrate the
    /// smallest running sequences over (KV transfer modelled via KvReady).
    fn rebalance_from(&mut self, id: InstanceId) {
        let peers = self.rebalance_peers(id);
        if peers.is_empty() {
            return;
        }
        let mine = self.decode_load(id);
        let total: u64 = peers.iter().map(|&p| self.decode_load(p)).sum();
        let mean = total as f64 / peers.len() as f64;
        if mean <= 0.0 || (mine as f64) < mean * Self::REBALANCE_TOLERANCE_HI {
            return;
        }
        // smallest sequences first: cheapest KV transfers
        let mut mine_reqs: Vec<(u64, RequestId)> = self.instances[id]
            .running
            .iter()
            .filter_map(|r| self.requests.get(r).map(|q| (q.context_len(), *r)))
            .collect();
        mine_reqs.sort();
        let mut moved = 0usize;
        let mut my_load = mine as f64;
        for (ctx, rid) in mine_reqs {
            if moved >= Self::REBALANCE_MAX_MOVES
                || my_load < mean * Self::REBALANCE_TOLERANCE_HI
            {
                break;
            }
            let target = peers
                .iter()
                .copied()
                .filter(|&p| p != id)
                .min_by_key(|&p| self.decode_load(p));
            let target = match target {
                Some(t) if (self.decode_load(t) as f64) < mean * Self::REBALANCE_TOLERANCE_LO => {
                    t
                }
                _ => break,
            };
            if self.instances[target].running.len() >= self.cfg.batch.max_decode_seqs
                || self.instances[target].kv_free() < ctx
            {
                break;
            }
            self.instances[id].running.retain(|x| *x != rid);
            self.instances[id].kv_tokens = self.instances[id].kv_tokens.saturating_sub(ctx);
            self.instances[target].running.push(rid);
            self.instances[target].kv_tokens += ctx;
            if let Some(r) = self.requests.get_mut(&rid) {
                r.migrations += 1;
            }
            self.migrations += 1;
            let delay = self.executor.kv_transfer_s(ctx);
            let t = self.queue.now();
            self.trace.instant(t, Some(target), Some(rid), InstantKind::Migration);
            self.trace.complete(t, Some(target), Some(rid), SpanPhase::KvHandoff, delay);
            self.queue.schedule_in(delay, Ev::KvReady(target));
            my_load -= ctx as f64;
            moved += 1;
        }
    }

    /// Place a request that just finished prefill into a decode batch.
    fn place_decode_for(&mut self, rid: RequestId, home: InstanceId, ctx: u64) {
        let colocated = matches!(self.cfg.mode, ServingMode::Colocated);
        // §3.1 latency-constrained decoupling: under xLLM-OOC, OFFLINE
        // decode may run in either pool (it is not latency-strict), which
        // is the capacity the co-location policy exploits
        let offline_flexible = matches!(self.cfg.colocation, Some((ColocationMode::XllmOoc, _)))
            && self.requests.get(&rid).map(|r| !r.is_online()).unwrap_or(false);
        let candidates: Vec<InstanceId> = if colocated || offline_flexible {
            self.alive((0..self.cfg.n_instances).collect())
        } else {
            self.alive(self.pools.decode_capable())
        };
        let views = self.views(&candidates);
        let prefer = if colocated || self.pools.kind(home).serves_decode() {
            Some(home)
        } else {
            None
        };
        let target = self
            .scheduler
            .place_decode(&views, prefer, ctx, self.cfg.batch.max_decode_seqs)
            .or_else(|| candidates.first().copied());
        let target = match target {
            Some(t) => t,
            None => {
                self.fail_request(rid);
                return;
            }
        };
        if target == home {
            self.instances[home].running.push(rid);
            self.kick(home);
        } else {
            // KV transfer (migration queue, FCFS): the target gets the
            // request after the transfer delay
            let delay = self.executor.kv_transfer_s(ctx);
            self.migrations += 1;
            self.instances[home].kv_tokens =
                self.instances[home].kv_tokens.saturating_sub(ctx);
            self.instances[target].kv_tokens += ctx;
            self.instances[target].running.push(rid);
            self.requests.get_mut(&rid).unwrap().migrations += 1;
            let t = self.queue.now();
            self.trace.instant(t, Some(target), Some(rid), InstantKind::Migration);
            self.trace.complete(t, Some(target), Some(rid), SpanPhase::KvHandoff, delay);
            self.queue.schedule_in(delay, Ev::KvReady(target));
        }
    }

    /// A request reached a terminal phase: record it and tell the
    /// executor.  (Named apart from the consuming [`Self::finish`] —
    /// the two used to collide under one name, which never compiled.)
    fn complete_request(&mut self, rid: RequestId) {
        self.prefill_home.remove(&rid);
        let now = self.queue.now();
        if let Some(r) = self.requests.get(&rid) {
            if r.decode_start_s.is_some() {
                self.trace.end(now, None, Some(rid), SpanPhase::Decode);
            }
            self.trace.instant(now, None, Some(rid), InstantKind::Completion);
            if let Some(o) = r.outcome() {
                self.report.record(o);
            }
        }
        self.executor.finished(rid, now);
        // terminal: drop all per-request state — live memory tracks
        // in-flight requests, not total submissions.  Look-ahead bubbles
        // referencing this id hit the same `get → None → continue` path
        // they already took for phase-terminal entries.
        self.requests.remove(&rid);
        self.specs.remove(&(rid as usize));
    }

    // --- monitoring / role switching -----------------------------------

    fn on_monitor(&mut self) {
        let now = self.queue.now();
        // executor policy re-planning rides the monitor cadence (EPLB
        // rebalances etc. — a default no-op for policy-free executors)
        self.executor.on_control_tick(now);
        // settle drained transitional instances
        for id in 0..self.instances.len() {
            let kind = self.pools.kind(id);
            if matches!(kind, PoolKind::PrefillToDecode | PoolKind::DecodeToPrefill) {
                let drained = match kind {
                    PoolKind::PrefillToDecode => self.instances[id].prefill_queue.is_empty(),
                    PoolKind::DecodeToPrefill => self.instances[id].running.is_empty(),
                    _ => false,
                };
                if drained {
                    self.pools.settle(id);
                }
            }
        }
        // SLO-aware role switching
        if let ServingMode::Disaggregated { dynamic: true, .. } = self.cfg.mode {
            let views: Vec<InstanceView> =
                (0..self.instances.len()).map(|i| self.view(i)).collect();
            let flips = plan_role_switches(
                &views,
                &self.pools,
                &self.scheduler.predictor,
                self.executor.cost(),
                &self.cfg.slo,
                0,
                2,
            );
            for f in flips {
                let inst = match f {
                    RoleFlip::ToPrefill(i) => {
                        self.pools.flip_to_prefill(i, 2);
                        i
                    }
                    RoleFlip::ToDecode(i) => {
                        self.pools.flip_to_decode(i);
                        i
                    }
                };
                self.trace.instant(now, Some(inst), None, InstantKind::RoleFlip);
            }
        }
        // keep kicking idle instances with queued work (e.g. after flips)
        for id in 0..self.instances.len() {
            self.kick(id);
        }
        if !self.all_done() {
            self.queue.schedule_in(self.cfg.monitor_interval_s, Ev::Monitor);
        } else {
            self.monitor_live = false; // revived by the next submit
        }
    }

    // --- faults ---------------------------------------------------------

    fn on_fault(&mut self, id: InstanceId) {
        let now = self.queue.now();
        self.trace.instant(now, Some(id), None, InstantKind::Fault);
        self.instances[id].failed = true;
        self.instances[id].busy = false;
        // drain the pipeline: the device work is lost, but every still
        // outstanding ticket gets its poll_complete (executor contract)
        // before the slots are forgotten; the pending IterDone events
        // become stale and are dropped by seq mismatch
        let tickets: Vec<IterationTicket> = self
            .inflight
            .get_mut(&id)
            .map(|q| q.drain(..).filter_map(|fl| fl.ticket).collect())
            .unwrap_or_default();
        for t in tickets {
            let _ = self.executor.poll_complete(t);
        }
        self.host_free[id] = now;
        self.device_free[id] = now;
        self.stage_free[id] = now;
        let owned = self.instances[id].owned_requests();
        for rid in owned {
            self.instances[id].evict(rid);
            let (ctx, phase) = match self.requests.get(&rid) {
                Some(r) => (r.context_len(), r.phase),
                None => continue,
            };
            let interrupted = InterruptedRequest {
                request: rid,
                context_tokens: ctx,
                // decode-phase requests have a DRAM replica via the global
                // cache when prefix caching is on; otherwise HBM-only
                replica_tier: if self.cfg.prefix_cache {
                    Some(Tier::Dram)
                } else {
                    Some(Tier::Hbm)
                },
            };
            let (action, _delay) = plan_recovery(&interrupted, self.executor.cost(), &self.xfer);
            self.recoveries += 1;
            match (phase, action) {
                (Phase::Decode, RecoveryAction::Migrate) => {
                    let home = self.prefill_home.get(&rid).copied().unwrap_or(id);
                    if let Some(r) = self.requests.get_mut(&rid) {
                        r.migrations += 1;
                    }
                    self.place_decode_for(rid, home, ctx);
                }
                (Phase::Decode, _) => {
                    // recompute: back to prefill from scratch.  Close
                    // whatever span is open and restart the attribution
                    // stamps — the re-run re-opens Prefill at its first
                    // re-submitted chunk.
                    if let Some(r) = self.requests.get_mut(&rid) {
                        if let Some(p) = r.open_span() {
                            self.trace.end(now, Some(id), Some(rid), p);
                        }
                        r.phase = Phase::Prefill;
                        r.prefilled = 0;
                        r.prefix_hit_tokens = 0;
                        r.preemptions += 1;
                        r.prefill_start_s = None;
                        r.decode_start_s = None;
                        self.trace.begin(now, Some(id), Some(rid), SpanPhase::Queue);
                        self.trace.instant(now, Some(id), Some(rid), InstantKind::Preemption);
                    }
                    self.route_prefill(rid);
                }
                (Phase::Prefill, _) => {
                    if let Some(r) = self.requests.get_mut(&rid) {
                        if r.prefill_start_s.take().is_some() {
                            self.trace.end(now, Some(id), Some(rid), SpanPhase::Prefill);
                            self.trace.begin(now, Some(id), Some(rid), SpanPhase::Queue);
                        }
                        r.prefilled = 0;
                    }
                    self.route_prefill(rid);
                }
                (Phase::Encode, _) => {
                    if let Some(r) = self.requests.get_mut(&rid) {
                        if r.encode_start_s.take().is_some() {
                            self.trace.end(now, Some(id), Some(rid), SpanPhase::Encode);
                            self.trace.begin(now, Some(id), Some(rid), SpanPhase::Queue);
                        }
                    }
                    self.route_encode(rid);
                }
                _ => {}
            }
        }
        self.instances[id].kv_tokens = 0;
        let recovery_s =
            self.cfg.recovery.recovery_s(self.executor.cost().model.weight_bytes());
        self.queue.schedule_at(now + recovery_s, Ev::Recover(id));
    }

    fn on_recover(&mut self, id: InstanceId) {
        self.trace.instant(self.queue.now(), Some(id), None, InstantKind::Recovery);
        self.instances[id].failed = false;
        self.kick(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::FixedCostExecutor as FixedCost;

    #[test]
    fn lifecycle_runs_on_any_executor() {
        let cfg = OrchestratorConfig { n_instances: 2, ..Default::default() };
        let workload: Vec<RequestSpec> =
            (0..8).map(|i| RequestSpec::text(i as f64 * 0.1, 64, 4)).collect();
        let n = workload.len();
        let (res, exec) = Orchestrator::new(cfg, FixedCost::new(0.01)).run(workload);
        assert_eq!(res.report.n_completed(), n);
        assert_eq!(exec.finished as usize, n, "executor told about every completion");
        assert!(exec.iterations > 0);
        assert!(!res.truncated);
    }

    #[test]
    fn max_events_cap_sets_truncated() {
        let cfg = OrchestratorConfig { n_instances: 1, max_events: 10, ..Default::default() };
        let workload: Vec<RequestSpec> =
            (0..50).map(|i| RequestSpec::text(i as f64 * 0.01, 256, 64)).collect();
        let (res, _) = Orchestrator::new(cfg, FixedCost::new(0.01)).run(workload);
        assert!(res.truncated, "tiny event cap must truncate the run");
        assert!(res.events >= 10);
    }

    #[test]
    fn steppable_api_matches_run() {
        let workload: Vec<RequestSpec> =
            (0..6).map(|i| RequestSpec::text(i as f64 * 0.2, 128, 8)).collect();
        let cfg = OrchestratorConfig { n_instances: 2, ..Default::default() };
        let (want, _) = Orchestrator::new(cfg.clone(), FixedCost::new(0.01)).run(workload.clone());
        let mut orch = Orchestrator::new(cfg, FixedCost::new(0.01));
        orch.start(workload);
        while orch.step() {}
        let (got, _) = orch.finish();
        assert_eq!(got.report.n_requests(), want.report.n_requests());
        assert_eq!(got.iterations, want.iterations);
        assert_eq!(got.events, want.events);
        assert_eq!(got.migrations, want.migrations);
    }

    #[test]
    fn submit_after_drain_revives_monitoring() {
        let cfg = OrchestratorConfig { n_instances: 1, ..Default::default() };
        let mut orch = Orchestrator::new(cfg, FixedCost::new(0.01));
        orch.start(vec![RequestSpec::text(0.0, 64, 4)]);
        while orch.step() {}
        assert_eq!(orch.n_recorded(), 1);
        // drained replica gets late work injected (control-plane path)
        orch.submit(RequestSpec::text(0.0, 64, 4));
        while orch.next_event_time().is_some() {
            orch.step();
        }
        let (res, _) = orch.finish();
        assert_eq!(res.report.n_completed(), 2, "late submit must complete");
    }

    #[test]
    fn drain_in_flight_covers_pending_and_running() {
        let cfg = OrchestratorConfig { n_instances: 1, ..Default::default() };
        let mut orch = Orchestrator::new(cfg, FixedCost::new(0.05));
        // two immediate long requests + one that never arrives before the kill
        orch.start(vec![
            RequestSpec::text(0.0, 256, 64),
            RequestSpec::text(0.0, 256, 64),
            RequestSpec::text(50.0, 64, 4),
        ]);
        for _ in 0..8 {
            orch.step();
        }
        assert_eq!(orch.n_recorded(), 0, "nothing completes in 8 events");
        let drained = orch.drain_in_flight();
        assert_eq!(drained.len(), 3, "pending arrival + in-flight all drained");
        assert!(drained.iter().any(|d| d.context_tokens > 0), "some progress was made");
        assert!(
            drained.iter().any(|d| d.context_tokens == 0),
            "the not-yet-arrived request has no context"
        );
        let (res, _) = orch.finish();
        assert_eq!(res.report.n_requests(), 0, "drained requests never hit the report");
    }

    #[test]
    fn terminal_requests_free_their_state() {
        // streaming replica over well-spaced arrivals: live per-request
        // state must track in-flight work, not total submissions, while
        // the sketch aggregates stay identical to a retaining run
        let cfg = OrchestratorConfig { n_instances: 2, ..Default::default() };
        let workload: Vec<RequestSpec> =
            (0..40).map(|i| RequestSpec::text(i as f64 * 0.5, 64, 4)).collect();
        let n = workload.len();
        let (want, _) = Orchestrator::new(cfg.clone(), FixedCost::new(0.01)).run(workload.clone());
        let mut orch = Orchestrator::new(cfg, FixedCost::new(0.01));
        orch.enable_streaming_report();
        orch.start(workload);
        let mut live_high = 0usize;
        loop {
            live_high = live_high.max(orch.requests.len()).max(orch.specs.len());
            if !orch.step() {
                break;
            }
        }
        let (res, _) = orch.finish();
        assert_eq!(res.report.n_completed(), n);
        assert!(res.report.outcomes.is_empty(), "streaming report retains no outcomes");
        assert!(
            (res.report.sketch.ttft_mean() - want.report.sketch.ttft_mean()).abs() < 1e-12,
            "sketch aggregates must not depend on retention"
        );
        assert!((res.report.horizon() - want.report.horizon()).abs() < 1e-12);
        assert!(
            live_high < n / 2,
            "live state must stay bounded by in-flight work: peak {live_high} of {n}"
        );
    }

    #[test]
    fn load_report_aggregates_instances() {
        let cfg = OrchestratorConfig { n_instances: 2, ..Default::default() };
        let mut orch = Orchestrator::new(cfg, FixedCost::new(0.05));
        orch.start(vec![
            RequestSpec::text(0.0, 512, 32),
            RequestSpec::text(0.0, 512, 32).offline(),
        ]);
        for _ in 0..6 {
            orch.step();
        }
        let rep = orch.load_report();
        assert!(rep.kv_capacity > 0);
        assert!(
            rep.queued_prefill_tokens + rep.running_tokens + rep.kv_used > 0,
            "two in-flight requests must show load: {rep:?}"
        );
        assert!((rep.online_fraction - 0.5).abs() < 1e-9, "1 of 2 in flight is online");
    }

    #[test]
    fn start_at_aligns_local_clock_with_fleet_time() {
        let cfg = OrchestratorConfig { n_instances: 1, ..Default::default() };
        let mut orch = Orchestrator::new(cfg, FixedCost::new(0.01));
        orch.start_at(Vec::new(), 12.5);
        assert_eq!(orch.now(), 12.5);
        // the first pending event (monitor) fires after fleet time, not
        // at the replica's local t=0.25
        let t = orch.next_event_time().expect("monitor scheduled");
        assert!(t >= 12.5, "first event at {t} predates fleet time");
        orch.submit_at(RequestSpec::text(0.0, 64, 4), 13.0);
        while orch.step() {}
        let (res, _) = orch.finish();
        assert_eq!(res.report.n_completed(), 1);
        let o = res.report.outcomes[0];
        assert!(o.finish_s >= 13.0, "work cannot run before fleet time");
    }

    #[test]
    fn adopted_chain_hits_the_local_cache() {
        let spec = {
            let mut s = RequestSpec::text(0.0, 1024, 4);
            s.prefix_group = 3;
            s.shared_prefix = 512;
            s
        };
        let cfg = OrchestratorConfig { n_instances: 1, prefix_cache: true, ..Default::default() };
        let chain = hash_chain(
            &prefix_tokens(spec.prefix_group, spec.shared_prefix),
            cfg.prefix_block_tokens as usize,
        );
        // cold replica: the first request misses
        let (cold, _) = Orchestrator::new(cfg.clone(), FixedCost::new(0.01)).run(vec![spec]);
        assert_eq!(cold.prefix_hits, 0);
        // adopted chain (planned migration landed here): the same first
        // request now hits
        let mut orch = Orchestrator::new(cfg, FixedCost::new(0.01));
        orch.adopt_chain(&chain);
        let (warm, _) = orch.run(vec![spec]);
        assert_eq!(warm.prefix_hits, 1, "migrated KV must serve the prefix");
    }

    #[test]
    fn token_granular_arrivals_credit_exact_prefix_tokens() {
        // 300 shared tokens = 4 full 64-token blocks + a 44-token tail:
        // block matching credits 256 per hit, the radix path all 300
        let mk = |t: f64| {
            let mut s = RequestSpec::text(t, 1024, 4);
            s.prefix_group = 9;
            s.shared_prefix = 300;
            s
        };
        let workload = vec![mk(0.0), mk(0.5), mk(1.0)];
        let block =
            OrchestratorConfig { n_instances: 1, prefix_cache: true, ..Default::default() };
        let token = OrchestratorConfig { prefix_token_granular: true, ..block.clone() };
        let (rb, _) = Orchestrator::new(block, FixedCost::new(0.01)).run(workload.clone());
        let (rt, _) = Orchestrator::new(token, FixedCost::new(0.01)).run(workload);
        assert_eq!(rb.report.n_completed(), 3);
        assert_eq!(rt.report.n_completed(), 3);
        assert_eq!(rb.prefix_hits, 2);
        assert_eq!(rt.prefix_hits, 2);
        assert_eq!(rb.prefix_hit_tokens, 2 * 256, "block matching rounds down to full blocks");
        assert_eq!(rt.prefix_hit_tokens, 2 * 300, "radix matching credits the sub-block tail");
    }

    #[test]
    fn depth2_completes_everything_and_bounds_inflight() {
        let cfg =
            OrchestratorConfig { n_instances: 2, pipeline_depth: 2, ..Default::default() };
        let workload: Vec<RequestSpec> =
            (0..10).map(|i| RequestSpec::text(i as f64 * 0.05, 128, 16)).collect();
        let n = workload.len();
        let (res, exec) = Orchestrator::new(cfg, FixedCost::new(0.01)).run(workload);
        assert_eq!(res.report.n_completed(), n);
        assert_eq!(exec.finished as usize, n);
        assert_eq!(exec.outstanding, 0, "every ticket polled by the end");
        assert!(
            exec.max_outstanding <= 4,
            "2 instances x depth 2 bounds the pipeline: {}",
            exec.max_outstanding
        );
        assert!(exec.max_outstanding >= 2, "look-ahead submission must actually happen");
        assert!(!res.truncated);
    }

    #[test]
    fn depth1_never_holds_a_ticket() {
        let cfg = OrchestratorConfig { n_instances: 2, ..Default::default() };
        let workload: Vec<RequestSpec> =
            (0..6).map(|i| RequestSpec::text(i as f64 * 0.1, 128, 8)).collect();
        let (_, exec) = Orchestrator::new(cfg, FixedCost::new(0.01)).run(workload);
        assert_eq!(
            exec.max_outstanding, 1,
            "depth 1 is the blocking contract: submit completes in place"
        );
    }

    #[test]
    fn warm_pipeline_hides_the_host_share() {
        // one long decode: depth 1 pays host + device per token, a warm
        // depth-2 pipeline pays device alone once it fills
        let workload = vec![RequestSpec::text(0.0, 64, 32)];
        let cfg1 = OrchestratorConfig { n_instances: 1, ..Default::default() };
        let cfg2 =
            OrchestratorConfig { n_instances: 1, pipeline_depth: 2, ..Default::default() };
        let (r1, _) =
            Orchestrator::new(cfg1, FixedCost::with_host(0.01, 0.004)).run(workload.clone());
        let (r2, _) = Orchestrator::new(cfg2, FixedCost::with_host(0.01, 0.004)).run(workload);
        assert_eq!(r1.report.n_completed(), 1);
        assert_eq!(r2.report.n_completed(), 1);
        let e1 = r1.report.e2e_summary().mean();
        let e2 = r2.report.e2e_summary().mean();
        assert!(e2 < e1, "pipelined E2E {e2} must beat blocking {e1}");
    }

    #[test]
    fn pp_ramp_overlaps_iterations_at_depth2() {
        // a sharded device group reports a drain tail (ramp_s): its first
        // pp stage frees up early, so consecutive iterations overlap by
        // ramp_s once the depth-2 pipeline is warm
        let workload = vec![RequestSpec::text(0.0, 64, 32)];
        let cfg =
            OrchestratorConfig { n_instances: 1, pipeline_depth: 2, ..Default::default() };
        let (flat, _) = Orchestrator::new(cfg.clone(), FixedCost::new(0.01)).run(workload.clone());
        let (ramped, _) =
            Orchestrator::new(cfg, FixedCost::with_ramp(0.01, 0.002)).run(workload);
        assert_eq!(flat.report.n_completed(), 1);
        assert_eq!(ramped.report.n_completed(), 1);
        let e_flat = flat.report.e2e_summary().mean();
        let e_ramp = ramped.report.e2e_summary().mean();
        assert!(e_ramp < e_flat, "pp overlap E2E {e_ramp} must beat flat {e_flat}");
    }

    #[test]
    fn pp_ramp_is_inert_at_depth1() {
        // depth 1 is the blocking contract: the next submit happens at or
        // after the previous completion, so an early stage frontier can
        // never be the binding term — bit-identical timelines
        let workload = vec![RequestSpec::text(0.0, 64, 32)];
        let cfg = OrchestratorConfig { n_instances: 1, ..Default::default() };
        let (flat, _) = Orchestrator::new(cfg.clone(), FixedCost::new(0.01)).run(workload.clone());
        let (ramped, _) =
            Orchestrator::new(cfg, FixedCost::with_ramp(0.01, 0.002)).run(workload);
        assert_eq!(
            flat.report.e2e_summary().mean().to_bits(),
            ramped.report.e2e_summary().mean().to_bits()
        );
        assert_eq!(flat.iterations, ramped.iterations);
    }

    #[test]
    fn load_report_carries_the_executor_shard() {
        let cfg = OrchestratorConfig { n_instances: 1, ..Default::default() };
        let mut exec = FixedCost::new(0.01);
        exec.cost.features.shard = crate::model::ShardSpec::new(2, 2, 4);
        let orch = Orchestrator::new(cfg, exec);
        let rep = orch.load_report();
        assert_eq!(rep.shard, crate::model::ShardSpec::new(2, 2, 4));
        assert_eq!(rep.devices(), 4);
    }

    #[test]
    fn depth2_fault_recovery_drains_the_pipeline() {
        let cfg = OrchestratorConfig {
            n_instances: 2,
            pipeline_depth: 2,
            faults: vec![(0.05, 0)],
            ..Default::default()
        };
        let workload: Vec<RequestSpec> =
            (0..8).map(|i| RequestSpec::text(i as f64 * 0.02, 256, 32)).collect();
        let n = workload.len();
        let (res, exec) = Orchestrator::new(cfg, FixedCost::new(0.01)).run(workload);
        assert_eq!(res.report.n_requests(), n, "every request accounted");
        assert_eq!(res.report.n_completed(), n, "survivor serves everything");
        assert_eq!(exec.outstanding, 0, "the fault drain polls every outstanding ticket");
        assert!(res.recoveries > 0, "the fault actually interrupted work");
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "non-positive duration")]
    fn zero_duration_executor_trips_the_debug_assertion() {
        // regression for the old `begin_iteration(...).max(1e-6)` clamp:
        // a broken executor now fails loudly instead of being silently
        // rewritten to a microsecond
        let cfg = OrchestratorConfig { n_instances: 1, ..Default::default() };
        let _ = Orchestrator::new(cfg, FixedCost::new(0.0)).run(vec![RequestSpec::text(0.0, 64, 4)]);
    }

    #[test]
    fn prefix_cache_sizing_comes_from_config() {
        // block granularity larger than the shared prefix => chains are
        // empty and nothing can hit; the default granularity hits
        let workload: Vec<RequestSpec> = (0..6)
            .map(|i| {
                let mut s = RequestSpec::text(i as f64 * 0.1, 1024, 4);
                s.prefix_group = 1;
                s.shared_prefix = 512;
                s
            })
            .collect();
        let base = OrchestratorConfig { n_instances: 1, prefix_cache: true, ..Default::default() };
        let coarse = OrchestratorConfig { prefix_block_tokens: 1 << 12, ..base.clone() };
        let (r_fine, _) = Orchestrator::new(base, FixedCost::new(0.01)).run(workload.clone());
        let (r_coarse, _) = Orchestrator::new(coarse, FixedCost::new(0.01)).run(workload);
        assert!(r_fine.prefix_hits > 0, "default 64-token blocks must hit");
        assert_eq!(r_coarse.prefix_hits, 0, "4096-token blocks cannot cover a 512-token prefix");
    }
}
