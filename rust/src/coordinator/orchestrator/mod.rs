//! The shared serving orchestrator: one request-lifecycle state machine
//! for both the discrete-event cluster simulator and the real PJRT
//! server (the paper's decoupled service-engine split, §2).
//!
//! The orchestrator owns the lifecycle — arrival → (encode) → dispatch →
//! chunked-prefill iterations → KV handoff → batched decode →
//! completion — plus dynamic PD role switching, online/offline
//! co-location admission, preemption, and fault recovery.  *How* an
//! iteration's work actually runs is delegated to an [`Executor`]:
//!
//! * [`crate::sim::executor::RooflineExecutor`] prices iterations with
//!   the roofline cost model (the Ascend-testbed substitute) — virtual
//!   time advances by the modelled step cost.
//! * `server::PjrtExecutor` executes iterations for real on the AOT
//!   PJRT artifacts — virtual time advances by measured wall time.
//!
//! Any future backend (batched PJRT, remote instance, quantized path)
//! drops in behind the same trait instead of forking the lifecycle
//! logic again.  See DESIGN.md §Orchestrator.

pub mod machine;

pub use machine::Orchestrator;

use crate::coordinator::batcher::BatchConfig;
use crate::coordinator::pools::InstanceId;
use crate::coordinator::request::RequestId;
use crate::coordinator::scheduler::DispatchPolicy;
use crate::metrics::{ServingReport, Slo};
use crate::service::colocation::ColocationConfig;
use crate::service::epd::EpdStrategy;
use crate::service::fault::RecoveryModel;
use crate::sim::roofline::CostModel;

/// How instances split work across phases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingMode {
    /// Every instance serves prefill + decode (chunked continuous batch).
    Colocated,
    /// PD disaggregation with `n_prefill` initial prefill instances;
    /// `dynamic` enables SLO-aware role switching (§3.2).
    Disaggregated { n_prefill: usize, dynamic: bool },
}

/// Online-offline co-location variants (Fig 23).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColocationMode {
    /// Offline requests treated exactly like online (baseline P/D).
    BaselinePd,
    /// Offline dispatched only when no online request is waiting.
    OnlinePriority,
    /// The paper's policy: latency-constrained pools + admission control
    /// + preemption (xLLM-OOC).
    XllmOoc,
}

/// One decode sequence scheduled into an iteration.
#[derive(Debug, Clone, Copy)]
pub struct DecodeWork {
    pub req: RequestId,
    /// Context tokens resident for this sequence (KV length).
    pub context_tokens: u64,
}

/// One (possibly partial) prefill chunk scheduled into an iteration.
#[derive(Debug, Clone, Copy)]
pub struct PrefillWork {
    pub req: RequestId,
    /// New prompt tokens computed this iteration.
    pub tokens: u64,
    /// Context already computed before this chunk.
    pub context_tokens: u64,
}

/// One multimodal encode task scheduled into an iteration.
#[derive(Debug, Clone, Copy)]
pub struct EncodeWork {
    pub req: RequestId,
    pub image_patches: u64,
}

/// The work selected for one forward iteration on one instance, handed
/// to the [`Executor`].
#[derive(Debug, Clone, Default)]
pub struct IterationWork {
    pub decodes: Vec<DecodeWork>,
    pub prefills: Vec<PrefillWork>,
    pub encodes: Vec<EncodeWork>,
}

impl IterationWork {
    pub fn is_empty(&self) -> bool {
        self.decodes.is_empty() && self.prefills.is_empty() && self.encodes.is_empty()
    }

    pub fn prefill_tokens(&self) -> u64 {
        self.prefills.iter().map(|p| p.tokens).sum()
    }
}

/// Timing of one iteration, split so the orchestrator can overlap the
/// host share with device execution (paper §4.2 async scheduling).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IterationOutcome {
    /// Host-side planning/dispatch cost (batch assembly, scheduling,
    /// launch prep).  Exposed at pipeline depth 1; hidden under the
    /// previous iteration's device time when the pipeline is warm.
    pub host_s: f64,
    /// Device execution time (modelled or measured).
    pub device_s: f64,
    /// Pipeline-parallel drain tail within `device_s`: the trailing
    /// window during which the replica's first pp stage is already idle
    /// and the *next* iteration's micro-batches may start filling the
    /// pipeline (second pipelining axis; see DESIGN.md §Sharding).
    /// 0.0 — the default, and always for `pp == 1` backends — keeps the
    /// timeline exactly on the per-device frontier.  Effective only at
    /// pipeline depth ≥ 2, like the host share.
    pub ramp_s: f64,
}

impl IterationOutcome {
    /// The blocking-contract duration: host + device back to back.
    pub fn total_s(&self) -> f64 {
        self.host_s + self.device_s
    }
}

/// Handle to an iteration accepted by [`Executor::submit_iteration`].
#[derive(Debug, Clone, Copy)]
pub struct IterationTicket {
    pub instance: InstanceId,
    /// Monotonic submission number (executor-assigned, never reused).
    /// The orchestrator matches completion events to pipeline slots with
    /// it, so completions from a pre-fault pipeline are recognizably
    /// stale.
    pub seq: u64,
    /// The executor's estimate of the outcome, made at submit time.
    /// Model-priced executors know the exact outcome up front (estimate
    /// == completion); real backends predict from their cost model and
    /// report the measured outcome at [`Executor::poll_complete`].
    pub est: IterationOutcome,
}

/// Raw KV data for one staged prefix chain, exported by a source
/// executor and imported by the target (§3.4 real cross-replica KV
/// movement).  Each entry is `(block hash, flat KV data for that
/// block's tokens)` — the layout is backend-private; the control plane
/// only ferries the payload between the two executors' hooks.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KvChainPayload {
    pub blocks: Vec<(u64, Vec<f32>)>,
}

impl KvChainPayload {
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Payload size in bytes (f32 elements × 4).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|(_, d)| d.len() * 4).sum()
    }
}

/// Backend executing the orchestrator's planned iterations.
///
/// The orchestrator plans *what* runs each iteration; the executor
/// decides *how long it takes* (and, for real backends, actually runs
/// it).  Virtual time advances by the reported durations, so a roofline
/// executor yields a discrete-event simulation while a PJRT executor
/// yields real serving with wall-clock metrics.
///
/// The contract is two-phase (paper §4.2 asynchronous scheduling):
/// [`Executor::submit_iteration`] begins the work without blocking the
/// caller, and [`Executor::poll_complete`] finishes it.  The
/// orchestrator submits up to [`OrchestratorConfig::pipeline_depth`]
/// iterations per instance before completing the oldest, so host-side
/// planning for iteration N+1 runs while iteration N is on the device.
/// Depth 1 recovers the old blocking behavior exactly: submit is
/// followed immediately by poll, and the full `host_s + device_s` span
/// is charged to the timeline.
///
/// Executors are `Send`: the fleet runtime steps each replica (and
/// therefore its executor) on its own thread in threaded mode, so every
/// backend must be movable across threads.  The real PJRT backend
/// already proves this — its engine core crosses onto a worker thread
/// at pipeline depth ≥ 2.
pub trait Executor: Send {
    /// Cost model backing the dispatch/prediction/role-switch heuristics
    /// (for real backends, a calibrated stand-in is fine — heuristics
    /// only compare relative magnitudes).
    fn cost(&self) -> &CostModel;

    /// Phase 1: begin executing `work` on `instance` at virtual time
    /// `now_s`.  Must not block on the device work: real executors hand
    /// the iteration to a worker thread, cost-model executors just price
    /// the step.  Returns a ticket whose `est` is the executor's best
    /// knowledge of the outcome at submit time.
    fn submit_iteration(
        &mut self,
        instance: InstanceId,
        now_s: f64,
        work: &IterationWork,
    ) -> IterationTicket;

    /// Phase 2: complete a submitted iteration, blocking (real backends)
    /// until the device work has finished.  Called at most once per
    /// ticket, in submission order per instance; tickets still
    /// outstanding when the orchestrator is finalized or the instance
    /// faults are either drained via this call or abandoned.
    fn poll_complete(&mut self, ticket: IterationTicket) -> IterationOutcome;

    /// The pre-async blocking contract, recovered: submit and complete
    /// in one call, returning the total duration in seconds.  Depth-1
    /// pipelining performs exactly this sequence.
    fn begin_iteration(&mut self, instance: InstanceId, now_s: f64, work: &IterationWork) -> f64 {
        let ticket = self.submit_iteration(instance, now_s, work);
        self.poll_complete(ticket).total_s()
    }

    /// Tokens emitted for decode request `req` in the iteration that
    /// just completed on `instance`.  Called once per scheduled decode,
    /// in plan order, at iteration completion (speculative decoding
    /// emits >1).  Default: one token per iteration.
    fn decode_emission(&mut self, instance: InstanceId, req: RequestId) -> u64 {
        let _ = (instance, req);
        1
    }

    /// KV-cache transfer latency between instances for `tokens` of
    /// context (PD handoff / migration).
    fn kv_transfer_s(&self, tokens: u64) -> f64 {
        self.cost().kv_transfer_s(tokens)
    }

    /// A request spec was admitted to this orchestrator (scheduled at
    /// `start` or injected by the control plane via `submit`).  Real
    /// backends materialize per-request inputs here — e.g. the PJRT
    /// executor synthesizes and queues the prompt for a fleet-routed
    /// request.  Called before the arrival event fires; default: no-op
    /// (model-priced executors need only the spec the planner carries).
    fn admitted(&mut self, req: RequestId, spec: &crate::workload::RequestSpec) {
        let _ = (req, spec);
    }

    /// Export the raw KV backing a staged prefix chain so the control
    /// plane can land it on another replica's executor (§3.4 planned
    /// rebalancing / warm start / graceful-drain migration).  Default:
    /// `None` — the movement stays *cost-only* (the control plane
    /// charges the `TransferEngine` delay and the target adopts the
    /// chain logically), which is exactly the pre-hook contract for
    /// model-priced executors.
    fn export_chain(&mut self, chain: &[u64]) -> Option<KvChainPayload> {
        let _ = chain;
        None
    }

    /// Land KV exported by a peer replica's [`Executor::export_chain`].
    /// Takes the payload by value — the control plane hands over its
    /// only copy, so real backends move the blocks in without cloning.
    /// Default: drop (cost-only contract — the logical adoption happens
    /// in the orchestrator's prefix cache via `adopt_chain`).
    fn import_chain(&mut self, payload: KvChainPayload) {
        let _ = payload;
    }

    /// A request left the orchestrator (completed or failed) at virtual
    /// time `now_s`.  Real executors release per-request resources
    /// (batch slot, pages) here.
    fn finished(&mut self, req: RequestId, now_s: f64) {
        let _ = (req, now_s);
    }

    /// Backend invariant check, called from debug assertions at every
    /// iteration boundary (e.g. `XTensorManager::check_invariants` for
    /// the PJRT executor).  Default: nothing to check.
    fn debug_check(&self) -> Result<(), String> {
        Ok(())
    }

    /// Periodic control-plane tick, fired from the orchestrator's
    /// monitor cadence: executor policy re-planning (e.g. EPLB
    /// routing-table rebalances with staged weight swaps, §4.4.2)
    /// runs here, off the per-iteration hot path.  Default: no
    /// policies to re-plan.
    fn on_control_tick(&mut self, now_s: f64) {
        let _ = now_s;
    }

    /// Install the trace handle executor-internal events (EPLB replans,
    /// calibration updates) are emitted through.  Installed by
    /// [`Orchestrator::set_trace`] alongside the orchestrator's own
    /// handle.  Default: executor has nothing to trace.
    fn set_trace(&mut self, trace: crate::obs::TraceHandle) {
        let _ = trace;
    }
}

/// Executor-agnostic orchestrator configuration: everything about the
/// serving *policy*, nothing about the backend (hardware, model, or
/// speculative-decoding parameters live in the executor).
#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    pub n_instances: usize,
    /// Dedicated encode instances (EPD E pool).
    pub n_encode: usize,
    pub mode: ServingMode,
    pub dispatch: DispatchPolicy,
    pub slo: Slo,
    pub batch: BatchConfig,
    pub colocation: Option<(ColocationMode, ColocationConfig)>,
    /// Multimodal phase placement (None = text-only serving).
    pub epd: Option<EpdStrategy>,
    /// Injected faults: (time, instance).
    pub faults: Vec<(f64, usize)>,
    pub recovery: RecoveryModel,
    pub monitor_interval_s: f64,
    /// Enable the global prefix cache (§3.4).
    pub prefix_cache: bool,
    /// Prefix-cache block granularity in tokens (§3.4 chain hashing —
    /// must match the control plane's global index granularity).
    pub prefix_block_tokens: u64,
    /// Token-granular prefix matching: arrivals match against the
    /// cache's radix index over token ids (exact matched-token credit,
    /// including sub-block tails) instead of whole hashed blocks, and
    /// the cache logs residency deltas for incremental heartbeat
    /// publishes.  Off (the default) preserves the block-aligned chain
    /// behavior bit-identically.
    pub prefix_token_granular: bool,
    /// Prefix-cache tier capacities in tokens (HBM / DRAM / SSD).
    pub prefix_hbm_tokens: u64,
    pub prefix_dram_tokens: u64,
    pub prefix_ssd_tokens: u64,
    /// Iterations kept in flight per instance (§4.2 async scheduling).
    /// 1 (the default) is the blocking contract: plan, execute, complete,
    /// plan again — host overhead fully exposed.  At depth D ≥ 2 the
    /// orchestrator plans up to D-1 iterations ahead against predicted
    /// request states, so the host share of an iteration hides under the
    /// previous iteration's device time.  Values are clamped to ≥ 1.
    pub pipeline_depth: usize,
    /// Termination cap on processed events — guards against pathological
    /// configs that never drain.  Hitting it sets [`RunResult::truncated`].
    pub max_events: u64,
}

impl Default for OrchestratorConfig {
    fn default() -> Self {
        OrchestratorConfig {
            n_instances: 1,
            n_encode: 0,
            mode: ServingMode::Colocated,
            dispatch: DispatchPolicy::SloAware,
            slo: Slo::UNCONSTRAINED,
            batch: BatchConfig::default(),
            colocation: None,
            epd: None,
            faults: Vec::new(),
            recovery: RecoveryModel::default(),
            monitor_interval_s: 0.25,
            prefix_cache: false,
            prefix_block_tokens: DEFAULT_PREFIX_BLOCK_TOKENS,
            prefix_token_granular: false,
            prefix_hbm_tokens: DEFAULT_PREFIX_HBM_TOKENS,
            prefix_dram_tokens: DEFAULT_PREFIX_DRAM_TOKENS,
            prefix_ssd_tokens: DEFAULT_PREFIX_SSD_TOKENS,
            pipeline_depth: 1,
            max_events: DEFAULT_MAX_EVENTS,
        }
    }
}

/// Default event cap (was a hard-coded constant inside the sim loop).
pub const DEFAULT_MAX_EVENTS: u64 = 200_000_000;

/// Default prefix-cache sizing (was hard-coded at the `TieredCache::new`
/// call in the iteration machine).
pub const DEFAULT_PREFIX_BLOCK_TOKENS: u64 = 64;
pub const DEFAULT_PREFIX_HBM_TOKENS: u64 = 1 << 22;
pub const DEFAULT_PREFIX_DRAM_TOKENS: u64 = 1 << 24;
pub const DEFAULT_PREFIX_SSD_TOKENS: u64 = 1 << 26;

/// Orchestrator run output: serving metrics + policy counters.
#[derive(Debug)]
pub struct RunResult {
    pub report: ServingReport,
    pub role_flips: u64,
    pub preemptions: u64,
    pub migrations: u64,
    pub recoveries: u64,
    pub prefix_hits: u64,
    /// Prompt tokens credited against the local prefix cache at
    /// admission (token-exact when `prefix_token_granular`, else the
    /// block-rounded credit).
    pub prefix_hit_tokens: u64,
    /// Prefill tokens admitted beyond free KV after the decode-growth
    /// reserve, summed over iterations (zero by construction under
    /// token-exact admission).
    pub admission_overcommit_tokens: u64,
    pub iterations: u64,
    pub events: u64,
    /// The run hit [`OrchestratorConfig::max_events`] and stopped before
    /// draining every request.
    pub truncated: bool,
    /// Per-instance (iterations, tokens generated) for utilization checks.
    pub per_instance: Vec<(u64, u64)>,
}

impl RunResult {
    /// Export the run's policy counters into the unified registry under
    /// stable `xllm_*` names (the serving-quality metrics come from
    /// [`ServingReport::export_metrics`]).
    pub fn export_metrics(&self, reg: &mut crate::obs::MetricsRegistry) {
        self.export_metrics_replica(reg, None);
    }

    /// Like [`Self::export_metrics`], but per-instance gauges carry a
    /// `replica` label so N fleet replicas don't overwrite each other
    /// (counters accumulate either way).
    pub fn export_metrics_replica(
        &self,
        reg: &mut crate::obs::MetricsRegistry,
        replica: Option<usize>,
    ) {
        reg.inc("xllm_role_flips_total", self.role_flips);
        reg.inc("xllm_preemptions_total", self.preemptions);
        reg.inc("xllm_migrations_total", self.migrations);
        reg.inc("xllm_recoveries_total", self.recoveries);
        reg.inc("xllm_prefix_hits_total", self.prefix_hits);
        reg.inc("xllm_index_prefix_hit_tokens_total", self.prefix_hit_tokens);
        reg.inc("xllm_index_admission_overcommit_tokens_total", self.admission_overcommit_tokens);
        reg.inc("xllm_iterations_total", self.iterations);
        reg.inc("xllm_events_total", self.events);
        let label = |i: usize| match replica {
            Some(r) => format!("{{replica=\"{r}\",instance=\"{i}\"}}"),
            None => format!("{{instance=\"{i}\"}}"),
        };
        for (i, (iters, tokens)) in self.per_instance.iter().enumerate() {
            reg.set_gauge(&format!("xllm_instance_iterations{}", label(i)), *iters as f64);
            reg.set_gauge(&format!("xllm_instance_tokens{}", label(i)), *tokens as f64);
        }
    }
}

/// Aggregate load snapshot a replica publishes with each heartbeat
/// lease renewal (produced by [`Orchestrator::load_report`], consumed
/// by the control plane's instance registry — defined here so the
/// coordinator layer never depends on its own consumers).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoadReport {
    /// Prompt tokens waiting in prefill queues across the replica.
    pub queued_prefill_tokens: u64,
    /// Context tokens of running decode sequences.
    pub running_tokens: u64,
    pub kv_used: u64,
    pub kv_capacity: u64,
    pub n_running: usize,
    pub n_queued: usize,
    /// Fraction of in-flight requests that are online (latency-bound) —
    /// drives the cross-replica §3.1 offline steering.
    pub online_fraction: f64,
    /// Device-group layout of this replica (`devices = tp * pp`) — the
    /// control plane's scaler prices replicas in devices, not heads.
    pub shard: crate::model::ShardSpec,
}

impl LoadReport {
    /// Devices this replica occupies (`shard.tp * shard.pp`).
    pub fn devices(&self) -> u32 {
        self.shard.devices()
    }
}

/// A request caught in flight when its orchestrator replica dies,
/// returned by [`Orchestrator::drain_in_flight`] so the control plane
/// can re-dispatch it onto a surviving replica (§3.5).
#[derive(Debug, Clone, Copy)]
pub struct InFlightSnapshot {
    /// The original request spec (arrival time preserved, so failover
    /// latency shows up in the re-dispatched request's E2E).
    pub spec: crate::workload::RequestSpec,
    /// Context tokens accumulated on the dead replica (lost KV that the
    /// survivor must recompute or re-stage).
    pub context_tokens: u64,
    /// The request had reached the decode phase (its prefill is the
    /// recompute cost fault recovery weighs against migration).
    pub decoding: bool,
}
