//! Stateless serving instance + runtime monitor (paper §3.2).
//!
//! An instance is "stateless" in the paper's sense: prefill/decode is an
//! attribute of the *request*, so the same instance can serve either phase
//! and switches roles by pool membership alone.  The instance tracks its
//! work sets, KV occupancy, and a runtime monitor collecting the metrics
//! the paper lists: number/length of prefill and decode requests, memory
//! usage, TTFT, TPOT, and token generation intervals.

use std::collections::VecDeque;

use crate::coordinator::batcher::BatchConfig;
use crate::coordinator::pools::InstanceId;
use crate::coordinator::request::RequestId;
use crate::sim::CostModel;

/// EMA-based runtime monitor (the paper's "Runtime Instance Monitor").
#[derive(Debug, Clone)]
pub struct Monitor {
    /// EMA of observed per-token decode interval (s).
    pub ema_token_interval: f64,
    /// EMA of observed TTFT on this instance (s).
    pub ema_ttft: f64,
    /// Iterations executed.
    pub iterations: u64,
    /// Tokens generated.
    pub tokens_generated: u64,
    alpha: f64,
    seeded_tpot: bool,
    seeded_ttft: bool,
}

impl Default for Monitor {
    fn default() -> Self {
        Monitor {
            ema_token_interval: 0.0,
            ema_ttft: 0.0,
            iterations: 0,
            tokens_generated: 0,
            alpha: 0.2,
            seeded_tpot: false,
            seeded_ttft: false,
        }
    }
}

impl Monitor {
    pub fn observe_token_interval(&mut self, dt: f64) {
        if !self.seeded_tpot {
            self.ema_token_interval = dt;
            self.seeded_tpot = true;
        } else {
            self.ema_token_interval =
                (1.0 - self.alpha) * self.ema_token_interval + self.alpha * dt;
        }
    }

    pub fn observe_ttft(&mut self, ttft: f64) {
        if !self.seeded_ttft {
            self.ema_ttft = ttft;
            self.seeded_ttft = true;
        } else {
            self.ema_ttft = (1.0 - self.alpha) * self.ema_ttft + self.alpha * ttft;
        }
    }

    pub fn observe_iteration(&mut self, tokens: u64) {
        self.iterations += 1;
        self.tokens_generated += tokens;
    }
}

/// One serving instance's mutable state in the cluster simulation.
#[derive(Debug, Clone)]
pub struct InstanceState {
    pub id: InstanceId,
    pub cost: CostModel,
    pub batch: BatchConfig,
    /// FCFS prefill queue (request ids).
    pub prefill_queue: VecDeque<RequestId>,
    /// Running decode set.
    pub running: Vec<RequestId>,
    /// Multimodal encode queue.
    pub encode_queue: VecDeque<RequestId>,
    /// KV transfers arriving (request, ready time) — FCFS migration queue.
    pub migrations: VecDeque<(RequestId, f64)>,
    /// Currently executing an iteration.
    pub busy: bool,
    /// Instance is down (fault injection).
    pub failed: bool,
    /// KV tokens resident (decode requests' contexts + finished prefills).
    pub kv_tokens: u64,
    pub monitor: Monitor,
}

impl InstanceState {
    pub fn new(id: InstanceId, cost: CostModel, batch: BatchConfig) -> InstanceState {
        InstanceState {
            id,
            cost,
            batch,
            prefill_queue: VecDeque::new(),
            running: Vec::new(),
            encode_queue: VecDeque::new(),
            migrations: VecDeque::new(),
            busy: false,
            failed: false,
            kv_tokens: 0,
            monitor: Monitor::default(),
        }
    }

    /// Any work pending?
    pub fn has_work(&self) -> bool {
        !self.prefill_queue.is_empty()
            || !self.running.is_empty()
            || !self.encode_queue.is_empty()
    }

    /// Is the instance idle with nothing queued (role-flip candidate)?
    pub fn is_drained(&self) -> bool {
        !self.busy && !self.has_work() && self.migrations.is_empty()
    }

    /// KV capacity remaining.
    pub fn kv_free(&self) -> u64 {
        self.batch.kv_capacity_tokens.saturating_sub(self.kv_tokens)
    }

    /// Remove a request id from every queue (fault recovery / migration).
    pub fn evict(&mut self, id: RequestId) {
        self.prefill_queue.retain(|&r| r != id);
        self.running.retain(|&r| r != id);
        self.encode_queue.retain(|&r| r != id);
        self.migrations.retain(|&(r, _)| r != id);
    }

    /// All request ids owned by this instance.
    pub fn owned_requests(&self) -> Vec<RequestId> {
        let mut out: Vec<RequestId> = self.prefill_queue.iter().copied().collect();
        out.extend(self.running.iter().copied());
        out.extend(self.encode_queue.iter().copied());
        out.extend(self.migrations.iter().map(|(r, _)| *r));
        out
    }
}

/// Immutable load snapshot used by the global scheduler.
#[derive(Debug, Clone, Copy)]
pub struct InstanceView {
    pub id: InstanceId,
    /// Prompt tokens waiting in the prefill queue.
    pub queued_prefill_tokens: u64,
    /// Total context tokens of running decodes.
    pub running_tokens: u64,
    pub n_running: usize,
    pub n_queued: usize,
    pub kv_used: u64,
    pub kv_capacity: u64,
    pub failed: bool,
    /// Monitor readings.
    pub ema_token_interval: f64,
    pub ema_ttft: f64,
}

impl InstanceView {
    pub fn kv_free(&self) -> u64 {
        self.kv_capacity.saturating_sub(self.kv_used)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ascend_910b, catalog};
    use crate::sim::EngineFeatures;

    fn inst() -> InstanceState {
        let cost = CostModel::new(
            ascend_910b(),
            catalog("Qwen3-8B").unwrap(),
            EngineFeatures::xllm(1),
        );
        InstanceState::new(0, cost, BatchConfig::default())
    }

    #[test]
    fn monitor_ema_tracks() {
        let mut m = Monitor::default();
        m.observe_token_interval(0.05);
        assert!((m.ema_token_interval - 0.05).abs() < 1e-12);
        for _ in 0..100 {
            m.observe_token_interval(0.10);
        }
        assert!((m.ema_token_interval - 0.10).abs() < 0.005);
    }

    #[test]
    fn drained_and_work_flags() {
        let mut i = inst();
        assert!(i.is_drained());
        i.prefill_queue.push_back(7);
        assert!(i.has_work());
        assert!(!i.is_drained());
        i.prefill_queue.clear();
        i.migrations.push_back((3, 1.0));
        assert!(!i.is_drained(), "in-flight migration blocks draining");
    }

    #[test]
    fn evict_removes_everywhere() {
        let mut i = inst();
        i.prefill_queue.push_back(1);
        i.running.push(1);
        i.encode_queue.push_back(1);
        i.migrations.push_back((1, 0.5));
        i.evict(1);
        assert!(i.owned_requests().is_empty());
    }

    #[test]
    fn kv_free_saturates() {
        let mut i = inst();
        i.kv_tokens = i.batch.kv_capacity_tokens + 10;
        assert_eq!(i.kv_free(), 0);
    }
}
