//! Online-offline co-location (paper §3.1 / Fig 23): sweep offline load
//! against a fixed online workload and watch the SLO violation rate under
//! three policies — baseline P/D, online-priority, and xLLM-OOC.
//!
//! ```bash
//! cargo run --release --example colocation
//! ```

use xllm::metrics::Slo;
use xllm::model::{ascend_910b, catalog};
use xllm::service::colocation::ColocationConfig;
use xllm::sim::cluster::{run, ClusterConfig, ColocationMode, ServingMode};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

fn main() {
    let online_rate = 3.0;
    let horizon = 90.0;
    let tpot = 0.08;
    let slo = Slo::tpot(tpot);

    println!("== online-offline co-location: online {online_rate} req/s, TPOT SLO {}ms ==", tpot * 1e3);
    println!(
        "{:<16} {:>12} {:>14} {:>14} {:>12}",
        "policy", "offline qps", "online SLO %", "offline tok/s", "preemptions"
    );

    for offline_rate in [0.5, 1.0, 2.0, 4.0] {
        for (name, mode) in [
            ("baseline-pd", ColocationMode::BaselinePd),
            ("online-priority", ColocationMode::OnlinePriority),
            ("xllm-ooc", ColocationMode::XllmOoc),
        ] {
            let mut cfg = ClusterConfig::new(
                4,
                ascend_910b(),
                catalog("Qwen3-8B").unwrap(),
                EngineFeatures::xllm(1),
            );
            cfg.slo = slo;
            cfg.mode = ServingMode::Disaggregated { n_prefill: 1, dynamic: true };
            cfg.colocation = Some((
                mode,
                ColocationConfig { online_tpot_s: tpot, ..Default::default() },
            ));
            let mut rng = Rng::new(21);
            let mut w = scenario("sharegpt").unwrap().generate(horizon, online_rate, &mut rng);
            w.extend(scenario("offline-docs").unwrap().generate(horizon, offline_rate, &mut rng));
            w.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
            let res = run(cfg, w);

            // split metrics by class using outcome token signatures is
            // imprecise; report the overall SLO attainment of online-style
            // requests (tpot-bound) and total offline progress
            let report = &res.report;
            let online_att: f64 = report
                .outcomes
                .iter()
                .filter(|o| o.output_tokens < 1024) // online mix
                .filter(|o| o.meets(&slo))
                .count() as f64
                / report
                    .outcomes
                    .iter()
                    .filter(|o| o.output_tokens < 1024)
                    .count()
                    .max(1) as f64;
            let offline_tokens: u64 = report
                .outcomes
                .iter()
                .filter(|o| o.output_tokens >= 1024 || o.input_tokens >= 2048)
                .map(|o| o.output_tokens)
                .sum();
            println!(
                "{:<16} {:>12.1} {:>13.1}% {:>14.1} {:>12}",
                name,
                offline_rate,
                online_att * 100.0,
                offline_tokens as f64 / horizon,
                res.preemptions,
            );
        }
        println!();
    }
    println!("(xllm-ooc should hold online SLO flat as offline load rises — Fig 23's shape)");
}
