//! Multimodal EPD disaggregation (paper §3.3) — two demonstrations:
//!
//! 1. The REAL encoder path: runs the AOT vision-encoder graph via PJRT on
//!    synthetic patch features (the E phase of an EPD pipeline).
//! 2. The EPD profiler + cluster simulation on a TextCaps-like workload,
//!    comparing the fused baseline against the profiler-chosen hybrid
//!    strategy (Fig 22's shape).
//!
//! ```bash
//! make artifacts && cargo run --release --example multimodal_epd
//! ```

use std::path::Path;

use xllm::metrics::Slo;
use xllm::model::{ascend_910b, catalog};
use xllm::runtime::Runtime;
use xllm::service::epd::{profile_all, EpdStrategy, ALL_STRATEGIES};
use xllm::sim::cluster::{run, ClusterConfig, ServingMode};
use xllm::sim::{CostModel, EngineFeatures};
use xllm::util::Rng;
use xllm::workload::scenario;

fn main() -> anyhow::Result<()> {
    // --- 1) real encode phase through PJRT -------------------------------
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.txt").exists() {
        let mut rt = Runtime::load(artifacts)?;
        let patches = vec![0.25f32; 16 * 32];
        let t0 = std::time::Instant::now();
        let emb = rt.encode(&patches)?;
        println!(
            "real encoder: {} patch embeddings of dim {} in {:.2} ms",
            16,
            emb.len() / 16,
            t0.elapsed().as_secs_f64() * 1e3
        );
    } else {
        println!("(artifacts/ missing — skipping the real encoder demo)");
    }

    // --- 2) EPD profiler --------------------------------------------------
    let cost = CostModel::new(ascend_910b(), catalog("Qwen2-7B").unwrap(), EngineFeatures::xllm(1));
    let tpot = 0.05;
    let (best, profiles) = profile_all(&cost, 576, 16, 16 * 1024, tpot);
    println!("\nEPD profiler (576 patches/image, TPOT SLO {} ms):", tpot * 1e3);
    for p in &profiles {
        println!(
            "  {:?}: max_encode_batch={} token_budget={} score={:.3}{}",
            p.strategy,
            p.max_encode_batch,
            p.token_budget,
            p.score,
            if p.strategy == best.strategy { "   <- selected" } else { "" }
        );
    }

    // --- 3) TextCaps serving under each strategy ---------------------------
    println!("\nTextCaps-like workload, 3 LM instances + 1 encode instance:");
    println!("{:<8} {:>10} {:>12} {:>12}", "strategy", "goodput", "mean TTFT", "mean E2E");
    let slo = Slo::interactive(2.0, tpot);
    for strategy in ALL_STRATEGIES {
        let mut cfg = ClusterConfig::new(
            3,
            ascend_910b(),
            catalog("Qwen2-7B").unwrap(),
            EngineFeatures::xllm(1),
        );
        cfg.n_encode = if strategy == EpdStrategy::EPD { 1 } else { 0 };
        cfg.epd = Some(strategy);
        cfg.slo = slo;
        cfg.mode = if strategy == EpdStrategy::Fused {
            ServingMode::Colocated
        } else {
            ServingMode::Disaggregated { n_prefill: 1, dynamic: false }
        };
        let mut rng = Rng::new(11);
        let w = scenario("textcaps").unwrap().generate(60.0, 25.0, &mut rng);
        let res = run(cfg, w);
        let mut report = res.report;
        println!(
            "{:<8} {:>8.2}/s {:>10.0}ms {:>10.2}s",
            format!("{strategy:?}"),
            report.goodput(&slo),
            report.ttft_summary().mean() * 1e3,
            report.e2e_summary().mean(),
        );
    }
    println!("\n(disaggregated strategies should beat Fused under load — Fig 22's shape)");
    Ok(())
}
