//! Quickstart: end-to-end serving on the REAL model through all three
//! layers (Pallas kernels → JAX AOT graphs → rust PJRT coordinator).
//!
//! Loads the AOT artifacts, serves a batch of requests through the full
//! stack (bucketed prefill, xTensor paging, continuous batched decode),
//! verifies the generations against single-request greedy decoding, and
//! reports latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::Path;

use xllm::config::ServeConfig;
use xllm::server::{synth_prompt, GenRequest, Server};

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== xLLM quickstart: real-model serving through the full stack ==");

    // --- batched serving -------------------------------------------------
    let cfg = ServeConfig { max_batch: 8, max_output_tokens: 24, ..ServeConfig::default() };
    let mut server = Server::new(artifacts, cfg)?;
    let n_requests = 24;
    for i in 0..n_requests {
        server.submit(GenRequest {
            id: i,
            prompt: synth_prompt(i, 16 + (i as usize % 4) * 24),
            max_new_tokens: 24,
        });
    }
    let t0 = std::time::Instant::now();
    let results = server.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    let mut report = server.report.clone();
    println!("requests          : {}", results.len());
    println!("wall time         : {wall:.3} s");
    println!("tokens generated  : {}", server.stats.tokens_generated);
    println!(
        "throughput        : {:.1} tok/s",
        server.stats.tokens_generated as f64 / wall
    );
    println!("mean TTFT         : {:.2} ms", report.ttft_summary().mean() * 1e3);
    println!("mean TPOT         : {:.2} ms", report.tpot_summary().mean() * 1e3);
    println!("p99 E2E           : {:.2} ms", report.e2e_summary().percentile(99.0) * 1e3);
    println!(
        "xTensor pages     : {} maps, {} reuse-remaps, {} premap hits",
        server.page_stats().maps,
        server.page_stats().remaps_from_reusable,
        server.page_stats().premapped_hits
    );
    println!(
        "graph cache       : {} compiles, {} hits",
        server.graph_stats().compiles,
        server.graph_stats().hits
    );

    // --- correctness: batched output == single-request output ------------
    println!("\nverifying batched generations against single-request decoding...");
    let mut solo = Server::new(
        artifacts,
        ServeConfig { max_batch: 1, max_output_tokens: 24, ..ServeConfig::default() },
    )?;
    for i in 0..4u64 {
        solo.submit(GenRequest {
            id: i,
            prompt: synth_prompt(i, 16 + (i as usize % 4) * 24),
            max_new_tokens: 24,
        });
    }
    let solo_results = solo.run_to_completion()?;
    for s in &solo_results {
        let batched = results.iter().find(|r| r.id == s.id).unwrap();
        assert_eq!(
            batched.tokens, s.tokens,
            "request {}: batched and solo generations diverged",
            s.id
        );
    }
    println!("OK — batched generations are bit-identical to solo decoding");

    println!(
        "\nsample generation (req 0, {} tokens): {:?}",
        results[0].tokens.len(),
        &results[0].tokens[..results[0].tokens.len().min(12)]
    );
    Ok(())
}
