// perf probe: breakdown of a real decode step (literal build vs execute vs copy-out)
use std::time::Instant;
fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new("artifacts");
    let mut rt = xllm::runtime::Runtime::load(dir)?;
    let dims = rt.model_dims("tiny")?;
    let b = 8;
    let mut kv = xllm::runtime::BatchKv::zeros(dims, b);
    let tokens = vec![1i32; b];
    // warm
    rt.decode("tiny", &mut kv, &tokens, &vec![1i32; b])?;
    let n = 50;
    let t0 = Instant::now();
    for i in 0..n {
        let pos = vec![(2 + i) as i32; b];
        rt.decode("tiny", &mut kv, &tokens, &pos)?;
    }
    let per = t0.elapsed().as_secs_f64() / n as f64;
    println!("decode b=8 full step: {:.3} ms ({:.0} tok/s)", per*1e3, 8.0/per);
    let cache_elems = kv.k.len();
    println!("cache elems per tensor: {} ({:.2} MB)", cache_elems, cache_elems as f64*4.0/1e6);
    Ok(())
}
