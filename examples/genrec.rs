//! Generative recommendation (paper §4.5): beam search over the REAL
//! model's logits with the valid-item trie mask, comparing the naive
//! full-sort host path against the optimized min-heap + early-termination
//! path (both must select identical beams).
//!
//! ```bash
//! make artifacts && cargo run --release --example genrec
//! ```

use std::path::Path;

use xllm::engine::genrec::{topk_desc, BeamSearcher, ValidItemTrie};
use xllm::runtime::{argmax, BatchKv, Runtime};
use xllm::util::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut rt = Runtime::load(artifacts)?;
    let dims = rt.model_dims("tiny")?;

    // synthetic item catalog: 64 items, 3-token codes (OneRec-style)
    let mut rng = Rng::new(5);
    let items: Vec<Vec<u32>> = (0..64)
        .map(|_| (0..3).map(|_| rng.range(1, 250) as u32).collect())
        .collect();
    let trie = ValidItemTrie::new(&items);
    println!("item catalog: {} items, {}-token codes", trie.n_items, trie.code_len);

    // user-context prompt -> prefill -> 3 masked beam-search steps
    let prompt: Vec<i32> = (0..24).map(|_| rng.range(1, 255) as i32).collect();
    let p = rt.prefill("tiny", &prompt)?;
    let beam_width = 4;
    let top_k = 8;

    // each beam keeps its own KV slot (batch bucket 4 = beam width)
    let mut kv = BatchKv::zeros(dims, beam_width);
    for slot in 0..beam_width {
        kv.write_prefill(slot, &p.k, &p.v, p.bucket_s, prompt.len());
    }
    // beams: (token prefix, log prob, last token, pos)
    let first = argmax(&p.last_logits) as i32;
    let mut beams: Vec<(Vec<u32>, f64)> = vec![(vec![], 0.0); 1];
    let mut last: Vec<i32> = vec![first; beam_width];
    let mut searcher = BeamSearcher::new(beam_width);
    let mut naive = BeamSearcher::new(beam_width);

    for step in 0..3 {
        // one batched decode over the beams (all share pos)
        let pos: Vec<i32> = (0..beam_width).map(|_| (prompt.len() + step) as i32).collect();
        let out = rt.decode("tiny", &mut kv, &last, &pos)?;
        // expansions per live beam: masked log-softmax top-k, descending
        let mut expansions: Vec<Vec<(u32, f64)>> = Vec::new();
        for (b, (prefix, lp)) in beams.iter().enumerate() {
            let logits = &out.logits[b * dims.vocab..(b + 1) * dims.vocab];
            let maxv = logits.iter().cloned().fold(f32::MIN, f32::max) as f64;
            let logz: f64 =
                (logits.iter().map(|&x| ((x as f64) - maxv).exp()).sum::<f64>()).ln() + maxv;
            let mask = trie.mask(prefix, dims.vocab);
            let scored: Vec<f64> = logits
                .iter()
                .zip(&mask)
                .map(|(&l, &m)| (l as f64 - logz) + m + lp)
                .collect();
            expansions.push(topk_desc(&scored, top_k));
        }
        // optimized and naive selection must agree
        let picks = searcher.step_optimized(&expansions);
        let check = naive.step_naive(&expansions);
        assert_eq!(picks.len(), check.len());
        for (a, b) in picks.iter().zip(&check) {
            assert_eq!((a.parent, a.token), (b.parent, b.token), "beam paths diverged");
        }
        // rebuild beams + KV slots from picks
        let old_kv = kv.clone();
        let mut new_beams = Vec::new();
        for (slot, c) in picks.iter().enumerate() {
            let mut seq = beams[c.parent].0.clone();
            seq.push(c.token);
            new_beams.push((seq, c.log_prob));
            kv.copy_slot_from(slot, &old_kv, c.parent, prompt.len() + step + 1);
            last[slot] = c.token as i32;
        }
        beams = new_beams;
        println!(
            "step {step}: kept {} beams, examined {}/{} candidates ({} early breaks)",
            beams.len(),
            searcher.stats.candidates_examined,
            searcher.stats.candidates_total,
            searcher.stats.early_breaks
        );
    }

    println!("\nrecommended items (beam order):");
    for (seq, lp) in &beams {
        assert!(trie.is_valid_item(seq), "emitted an invalid item: {seq:?}");
        println!("  item {:?}  log_prob {:.3}", seq, lp);
    }
    println!(
        "\nhost-side savings: examined {}/{} candidates; naive examined {}",
        searcher.stats.candidates_examined,
        searcher.stats.candidates_total,
        naive.stats.candidates_examined
    );
    println!("all emitted codes are valid catalog items — §4.5.2 filtering holds");
    Ok(())
}
