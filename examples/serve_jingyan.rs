//! JingYan-style serving scenario (paper §5.1.2) on the cluster simulator:
//! the AI shopping assistant's conversational workload under dynamic PD
//! disaggregation, comparing the xLLM configuration against the vLLM-like
//! and MindIE-like baselines at matched load.
//!
//! ```bash
//! cargo run --release --example serve_jingyan
//! ```

use xllm::metrics::Slo;
use xllm::model::{ascend_910b, catalog};
use xllm::sim::cluster::{run, ClusterConfig, ServingMode};
use xllm::sim::EngineFeatures;
use xllm::util::Rng;
use xllm::workload::scenario;

fn main() {
    let model = catalog("Qwen3-8B").unwrap();
    let slo = Slo::interactive(2.0, 0.05);
    let rate = 14.0;
    let horizon = 120.0;

    println!("== JingYan scenario: Qwen3-8B, 4x 910B, TPOT SLO 50 ms ==");
    println!(
        "{:<10} {:>12} {:>12} {:>10} {:>10} {:>8} {:>7}",
        "framework", "out tok/s", "mean TTFT", "mean TPOT", "SLO att.", "flips", "migr"
    );

    for (name, features) in [
        ("xllm", EngineFeatures::xllm(1)),
        ("mindie", EngineFeatures::mindie(1)),
        ("vllm", EngineFeatures::vllm(1)),
    ] {
        let mut cfg = ClusterConfig::new(4, ascend_910b(), model.clone(), features);
        cfg.slo = slo;
        // xLLM runs dynamic PD; baselines use the static colocated layout
        cfg.mode = if name == "xllm" {
            ServingMode::Disaggregated { n_prefill: 1, dynamic: true }
        } else {
            ServingMode::Colocated
        };
        cfg.prefix_cache = name == "xllm";
        let mut rng = Rng::new(42);
        let w = scenario("jingyan").unwrap().generate(horizon, rate, &mut rng);
        let res = run(cfg, w);
        let mut report = res.report;
        println!(
            "{:<10} {:>12.1} {:>10.0}ms {:>8.1}ms {:>9.1}% {:>8} {:>7}",
            name,
            report.output_throughput(),
            report.ttft_summary().mean() * 1e3,
            report.tpot_summary().mean() * 1e3,
            report.slo_attainment(&slo) * 100.0,
            res.role_flips,
            res.migrations,
        );
    }

    println!("\n(xLLM should lead on throughput and SLO attainment — Fig 16's shape)");
}
