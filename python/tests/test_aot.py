"""AOT pipeline tests: lowering produces parseable HLO text + valid weights.

These tests exercise the build path the rust runtime consumes.  They use
--quick mode (one bucket per graph) to keep CI time bounded; `make
artifacts` builds the full bucket set.
"""

import os
import struct

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    aot.build_all(str(d), quick=True)
    return str(d)


def test_manifest_exists_and_parses(outdir):
    path = os.path.join(outdir, "manifest.txt")
    assert os.path.exists(path)
    graphs, models = [], []
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            kind = parts[0]
            kv = dict(p.split("=", 1) for p in parts[1:])
            if kind == "graph":
                graphs.append(kv)
            elif kind == "model":
                models.append(kv)
    assert {g["name"] for g in graphs} >= {
        "prefill_s16",
        "decode_b1",
        "verify_b1_m4",
        "draft_decode_b1",
        "encode",
        "moe",
    }
    assert {m["name"] for m in models} == {"tiny", "draft", "enc", "moe"}
    for g in graphs:
        assert os.path.exists(os.path.join(outdir, g["file"]))


def test_hlo_text_is_hlo(outdir):
    with open(os.path.join(outdir, "decode_b1.hlo.txt")) as f:
        text = f.read()
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True -> root is a tuple
    assert "tuple(" in text or "ROOT" in text


def test_weights_bin_roundtrip(outdir):
    path = os.path.join(outdir, "weights.bin")
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == b"XLLMW001"
    (n,) = struct.unpack_from("<I", data, 8)
    off = 12
    names = []
    for _ in range(n):
        (nl,) = struct.unpack_from("<I", data, off)
        off += 4
        name = data[off : off + nl].decode()
        off += nl
        (nd,) = struct.unpack_from("<I", data, off)
        off += 4
        dims = struct.unpack_from(f"<{nd}I", data, off)
        off += 4 * nd
        count = 1
        for d in dims:
            count *= d
        off += 4 * count
        names.append(name)
    assert off == len(data), "weights.bin has trailing bytes"
    assert "tiny/embed" in names
    assert "draft/embed" in names
    assert "enc/enc.w1" in names
    assert "moe/moe.gate" in names
    # parameter order of the tiny set must match init_weights order
    tiny_names = [f"tiny/{k}" for k, _ in M.init_weights(M.TINY)]
    assert [x for x in names if x.startswith("tiny/")] == tiny_names


def test_weight_tensor_count_matches_manifest(outdir):
    with open(os.path.join(outdir, "manifest.txt")) as f:
        for line in f:
            if line.startswith("weights "):
                kv = dict(p.split("=", 1) for p in line.split()[1:])
                declared = int(kv["n_tensors"])
    with open(os.path.join(outdir, "weights.bin"), "rb") as f:
        f.seek(8)
        (n,) = struct.unpack("<I", f.read(4))
    assert n == declared


def test_hlo_has_no_serialized_proto_markers(outdir):
    """Guard: interchange must be text, never .serialize() output."""
    for fname in os.listdir(outdir):
        if fname.endswith(".hlo.txt"):
            with open(os.path.join(outdir, fname), "rb") as f:
                head = f.read(64)
            assert b"HloModule" in head, f"{fname} does not start with HLO text"
