"""L2 model correctness: prefill/decode/verify consistency + shape contracts.

The key invariant for a serving stack: batched, cache-carrying decode must
produce exactly the logits that a from-scratch full prefill over the same
token history produces.  If this holds, the rust coordinator can freely mix
prefill/decode scheduling without changing model semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(max_seq=32)  # small Smax to keep tests fast
WS = M.init_weights(CFG)


def greedy(logits):
    return int(jnp.argmax(logits))


def make_cache(b):
    shape = (CFG.n_layers, b, CFG.n_heads, CFG.max_seq, CFG.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def seed_cache_from_prefill(kc, vc, slot, k, v, length):
    """Copy a prefill [L,H,S,Dh] KV into batch slot ``slot`` of the cache."""
    kc = kc.at[:, slot, :, :length].set(k[:, :, :length].transpose(0, 1, 2, 3))
    vc = vc.at[:, slot, :, :length].set(v[:, :, :length])
    return kc, vc


def test_prefill_shapes():
    tokens = jnp.arange(16, dtype=jnp.int32) % CFG.vocab
    logits, k, v = M.prefill(WS, CFG, tokens)
    assert logits.shape == (16, CFG.vocab)
    assert k.shape == (CFG.n_layers, CFG.n_heads, 16, CFG.d_head)
    assert v.shape == k.shape


def test_prefill_padding_is_harmless():
    """Positions before the true length are unaffected by pad tokens."""
    base = jnp.asarray([5, 17, 200, 3, 90, 41, 7, 9], jnp.int32)
    l1, _, _ = M.prefill(WS, CFG, base)
    padded = jnp.concatenate([base, jnp.full((8,), 99, jnp.int32)])
    l2, _, _ = M.prefill(WS, CFG, padded)
    np.testing.assert_allclose(l1, l2[:8], rtol=1e-5, atol=1e-5)


def test_decode_matches_prefill():
    """Prefill(n) + decode steps == prefill(n+k) at every step."""
    prompt = jnp.asarray([1, 2, 3, 4, 5, 6, 7, 8], jnp.int32)
    n = prompt.shape[0]
    logits_p, k, v = M.prefill(WS, CFG, prompt)
    kc, vc = make_cache(1)
    kc, vc = seed_cache_from_prefill(kc, vc, 0, k, v, n)

    token = greedy(logits_p[n - 1])
    history = list(map(int, prompt)) + [token]
    for step in range(4):
        pos = jnp.asarray([n + step], jnp.int32)
        logits_d, kc, vc = M.decode(WS, CFG, jnp.asarray([token], jnp.int32), pos, kc, vc)
        # oracle: full prefill over the whole history
        full_logits, _, _ = M.prefill(WS, CFG, jnp.asarray(history, jnp.int32))
        np.testing.assert_allclose(
            logits_d[0], full_logits[-1], rtol=2e-4, atol=2e-4
        )
        token = greedy(logits_d[0])
        history.append(token)


def test_decode_batch_equals_individual():
    """Batch decode must equal per-sequence decode (no cross-talk)."""
    prompts = [
        jnp.asarray([1, 2, 3, 4], jnp.int32),
        jnp.asarray([9, 8, 7, 6, 5, 4], jnp.int32),
    ]
    kc, vc = make_cache(2)
    lengths, next_tokens = [], []
    for i, p in enumerate(prompts):
        logits, k, v = M.prefill(WS, CFG, p)
        kc, vc = seed_cache_from_prefill(kc, vc, i, k, v, p.shape[0])
        lengths.append(p.shape[0])
        next_tokens.append(greedy(logits[p.shape[0] - 1]))

    pos = jnp.asarray(lengths, jnp.int32)
    toks = jnp.asarray(next_tokens, jnp.int32)
    batched, _, _ = M.decode(WS, CFG, toks, pos, kc, vc)

    for i, p in enumerate(prompts):
        kci, vci = make_cache(1)
        _, k, v = M.prefill(WS, CFG, p)
        kci, vci = seed_cache_from_prefill(kci, vci, 0, k, v, p.shape[0])
        single, _, _ = M.decode(
            WS,
            CFG,
            jnp.asarray([next_tokens[i]], jnp.int32),
            jnp.asarray([lengths[i]], jnp.int32),
            kci,
            vci,
        )
        np.testing.assert_allclose(batched[i], single[0], rtol=1e-4, atol=1e-4)


def test_verify_matches_sequential_decode():
    """verify(M tokens) logits == M sequential decode steps' logits."""
    prompt = jnp.asarray([3, 1, 4, 1, 5, 9], jnp.int32)
    n = prompt.shape[0]
    cand = jnp.asarray([[2, 6, 5, 3]], jnp.int32)  # candidates to score
    m = cand.shape[1]

    _, k, v = M.prefill(WS, CFG, prompt)
    kc, vc = make_cache(1)
    kc, vc = seed_cache_from_prefill(kc, vc, 0, k, v, n)
    vlogits, _, _ = M.verify(WS, CFG, cand, jnp.asarray([n], jnp.int32), kc, vc)

    kc2, vc2 = make_cache(1)
    kc2, vc2 = seed_cache_from_prefill(kc2, vc2, 0, k, v, n)
    for j in range(m):
        dl, kc2, vc2 = M.decode(
            WS,
            CFG,
            cand[:, j],
            jnp.asarray([n + j], jnp.int32),
            kc2,
            vc2,
        )
        np.testing.assert_allclose(vlogits[0, j], dl[0], rtol=2e-4, atol=2e-4)


def test_verify_updates_cache_like_decode():
    prompt = jnp.asarray([10, 20, 30], jnp.int32)
    n = prompt.shape[0]
    cand = jnp.asarray([[7, 8, 9, 11]], jnp.int32)
    _, k, v = M.prefill(WS, CFG, prompt)
    kc, vc = make_cache(1)
    kc, vc = seed_cache_from_prefill(kc, vc, 0, k, v, n)
    _, kv1, vv1 = M.verify(WS, CFG, cand, jnp.asarray([n], jnp.int32), kc, vc)

    kc2, vc2 = make_cache(1)
    kc2, vc2 = seed_cache_from_prefill(kc2, vc2, 0, k, v, n)
    for j in range(4):
        _, kc2, vc2 = M.decode(WS, CFG, cand[:, j], jnp.asarray([n + j], jnp.int32), kc2, vc2)
    np.testing.assert_allclose(kv1, kc2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(vv1, vc2, rtol=2e-4, atol=2e-4)


def test_encoder_shapes_and_determinism():
    ew = M.init_encoder_weights(M.ENC)
    patches = jnp.ones((M.ENC.n_patches, M.ENC.d_patch), jnp.float32)
    (emb,) = M.encode(ew, M.ENC, patches)
    assert emb.shape == (M.ENC.n_patches, M.ENC.d_model)
    (emb2,) = M.encode(ew, M.ENC, patches)
    np.testing.assert_array_equal(emb, emb2)


def test_moe_block_runs():
    mw = M.init_moe_weights(M.MOE)
    x = jax.random.normal(jax.random.PRNGKey(0), (M.MOE.n_tokens, M.MOE.d_model))
    (y,) = M.moe_block(mw, M.MOE, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_weights_deterministic():
    w1 = M.init_weights(CFG)
    w2 = M.init_weights(CFG)
    for (n1, a1), (n2, a2) in zip(w1, w2):
        assert n1 == n2
        np.testing.assert_array_equal(a1, a2)


def test_param_count_matches_config():
    total = sum(int(np.prod(a.shape)) for _, a in M.init_weights(M.TINY))
    assert total == M.TINY.n_params
