"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes and dtypes; assert_allclose against ref.py.
This is the CORE numeric correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as ka
from compile.kernels import moe as km
from compile.kernels import ref

SETTINGS = dict(max_examples=15, deadline=None)


def rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=3e-5, atol=3e-5)


@st.composite
def prefill_shapes(draw):
    h = draw(st.sampled_from([1, 2, 4]))
    s = draw(st.sampled_from([4, 8, 16, 32, 64]))
    dh = draw(st.sampled_from([4, 8, 16]))
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    return h, s, dh, dtype


@given(prefill_shapes())
@settings(**SETTINGS)
def test_mha_prefill_matches_ref(shape):
    h, s, dh, dtype = shape
    q, k, v = (rand(i, (h, s, dh), dtype) for i in range(3))
    out = ka.mha_prefill(q, k, v)
    want = ref.mha_prefill_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


@st.composite
def decode_shapes(draw):
    b = draw(st.sampled_from([1, 2, 4, 8]))
    h = draw(st.sampled_from([1, 2, 4]))
    s = draw(st.sampled_from([8, 16, 64]))
    dh = draw(st.sampled_from([4, 16]))
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    pos = draw(st.lists(st.integers(0, s - 1), min_size=b, max_size=b))
    return b, h, s, dh, dtype, pos


@given(decode_shapes())
@settings(**SETTINGS)
def test_decode_attention_matches_ref(shape):
    b, h, s, dh, dtype, pos = shape
    q = rand(0, (b, h, dh), dtype)
    k = rand(1, (b, h, s, dh), dtype)
    v = rand(2, (b, h, s, dh), dtype)
    pos = jnp.asarray(pos, jnp.int32)
    out = ka.decode_attention(q, k, v, pos)
    want = ref.decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


@st.composite
def spec_shapes(draw):
    b = draw(st.sampled_from([1, 2, 4]))
    m = draw(st.sampled_from([1, 2, 4]))
    h = draw(st.sampled_from([1, 4]))
    s = draw(st.sampled_from([16, 64]))
    dh = draw(st.sampled_from([8, 16]))
    dtype = draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    pos = draw(st.lists(st.integers(0, s - m), min_size=b, max_size=b))
    return b, m, h, s, dh, dtype, pos


@given(spec_shapes())
@settings(**SETTINGS)
def test_spec_attention_matches_ref(shape):
    b, m, h, s, dh, dtype, pos = shape
    q = rand(0, (b, m, h, dh), dtype)
    k = rand(1, (b, h, s, dh), dtype)
    v = rand(2, (b, h, s, dh), dtype)
    pos = jnp.asarray(pos, jnp.int32)
    out = ka.spec_attention(q, k, v, pos)
    want = ref.spec_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), **tol(dtype)
    )


def test_spec_m1_equals_decode():
    """spec_attention with M=1 must agree with decode_attention."""
    b, h, s, dh = 3, 4, 32, 16
    q = rand(0, (b, h, dh), jnp.float32)
    k = rand(1, (b, h, s, dh), jnp.float32)
    v = rand(2, (b, h, s, dh), jnp.float32)
    pos = jnp.asarray([0, 7, 31], jnp.int32)
    dec = ka.decode_attention(q, k, v, pos)
    sp = ka.spec_attention(q[:, None], k, v, pos)[:, 0]
    np.testing.assert_allclose(dec, sp, rtol=1e-5, atol=1e-5)


def test_decode_masks_future_slots():
    """Entries past pos must not influence the output."""
    b, h, s, dh = 2, 2, 16, 8
    q = rand(0, (b, h, dh), jnp.float32)
    k = rand(1, (b, h, s, dh), jnp.float32)
    v = rand(2, (b, h, s, dh), jnp.float32)
    pos = jnp.asarray([3, 9], jnp.int32)
    out1 = ka.decode_attention(q, k, v, pos)
    # poison everything after pos
    idx = jnp.arange(s)[None, None, :, None]
    poison = jnp.where(idx > pos[:, None, None, None], 1e6, 0.0)
    out2 = ka.decode_attention(q, k + poison, v + poison, pos)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_prefill_causality():
    """Perturbing token t must not change outputs at positions < t."""
    h, s, dh = 2, 16, 8
    q = rand(0, (h, s, dh), jnp.float32)
    k = rand(1, (h, s, dh), jnp.float32)
    v = rand(2, (h, s, dh), jnp.float32)
    out1 = ka.mha_prefill(q, k, v)
    k2 = k.at[:, 10:].add(100.0)
    v2 = v.at[:, 10:].add(100.0)
    out2 = ka.mha_prefill(q, k2, v2)
    np.testing.assert_allclose(out1[:, :10], out2[:, :10], rtol=1e-5, atol=1e-5)


@st.composite
def moe_shapes(draw):
    t = draw(st.sampled_from([1, 4, 8, 32]))
    d = draw(st.sampled_from([8, 16]))
    f = draw(st.sampled_from([16, 32]))
    e = draw(st.sampled_from([1, 2, 4, 8]))
    experts = draw(st.lists(st.integers(0, e - 1), min_size=t, max_size=t))
    return t, d, f, e, experts


@given(moe_shapes())
@settings(**SETTINGS)
def test_moe_ffn_matches_ref(shape):
    t, d, f, e, experts = shape
    x = rand(0, (t, d), jnp.float32)
    w1 = rand(1, (e, d, f), jnp.float32) * 0.2
    b1 = rand(2, (e, f), jnp.float32) * 0.1
    w2 = rand(3, (e, f, d), jnp.float32) * 0.2
    b2 = rand(4, (e, d), jnp.float32) * 0.1
    expert = jnp.asarray(experts, jnp.int32)
    out = km.moe_ffn(x, w1, b1, w2, b2, expert)
    want = ref.moe_ffn_ref(x, w1, b1, w2, b2, expert)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


def test_moe_routing_partition():
    """Every token's output equals its own expert's FFN applied alone."""
    t, d, f, e = 8, 8, 16, 4
    x = rand(0, (t, d), jnp.float32)
    w1 = rand(1, (e, d, f), jnp.float32) * 0.2
    b1 = jnp.zeros((e, f))
    w2 = rand(3, (e, f, d), jnp.float32) * 0.2
    b2 = jnp.zeros((e, d))
    expert = jnp.asarray([0, 1, 2, 3, 3, 2, 1, 0], jnp.int32)
    out = km.moe_ffn(x, w1, b1, w2, b2, expert)
    for i in range(t):
        ei = int(expert[i])
        want = jax.nn.gelu(x[i] @ w1[ei]) @ w2[ei]
        np.testing.assert_allclose(out[i], want, rtol=1e-4, atol=1e-4)


def test_route_top1_bounds():
    x = rand(0, (16, 8), jnp.float32)
    g = rand(1, (8, 4), jnp.float32)
    r = km.route_top1(x, g)
    assert r.dtype == jnp.int32
    assert int(r.min()) >= 0 and int(r.max()) < 4
