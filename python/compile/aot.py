"""AOT: lower every L2 graph to HLO *text* + dump weights for the rust side.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --outdir ../artifacts

Produces:
  artifacts/<graph>.hlo.txt   — one XLA HLO module per (graph, shape bucket)
  artifacts/weights.bin       — all weight tensors (binary, see format below)
  artifacts/manifest.txt      — graph index the rust runtime parses

Interchange is HLO TEXT, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <=
INT_MAX``).  The text parser reassigns ids, so text round-trips cleanly.
See /opt/xla-example/README.md.

weights.bin format (little-endian):
  magic   b"XLLMW001"
  u32     n_tensors
  per tensor:
    u32   name_len;  name (utf-8, e.g. "tiny/embed")
    u32   ndim;  u32 dims[ndim]
    f32   data[prod(dims)]

The manifest is line-oriented ``key=value`` records:
  model  name=tiny vocab=256 d_model=64 ...
  graph  name=decode_b4 file=decode_b4.hlo.txt weights=tiny kind=decode b=4 ...
"""

from __future__ import annotations

import argparse
import os
import struct
import sys
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

PREFILL_BUCKETS = [16, 32, 64, 128]
DECODE_BUCKETS = [1, 2, 4, 8]
VERIFY_BUCKETS = [(1, 4), (4, 4)]
DRAFT_DECODE_BUCKETS = [1, 4]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights(path: str, sets: Sequence[Tuple[str, M.Weights]]) -> int:
    """Dump all weight sets to weights.bin; returns tensor count."""
    tensors: List[Tuple[str, np.ndarray]] = []
    for set_name, ws in sets:
        for name, arr in ws:
            tensors.append((f"{set_name}/{name}", np.asarray(arr, np.float32)))
    with open(path, "wb") as f:
        f.write(b"XLLMW001")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype("<f4").tobytes())
    return len(tensors)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def cache_spec(cfg: M.ModelConfig, b: int):
    return spec((cfg.n_layers, b, cfg.n_heads, cfg.max_seq, cfg.d_head))


def lower_graph(fn: Callable, ws: M.Weights, arg_specs) -> str:
    """Lower fn(weight_arrays..., *args) with weights as leading params."""
    names = [n for n, _ in ws]
    w_specs = [spec(a.shape) for _, a in ws]

    def wrapper(wlist, *args):
        return fn(list(zip(names, wlist)), *args)

    lowered = jax.jit(wrapper).lower(w_specs, *arg_specs)
    return to_hlo_text(lowered)


def model_manifest_line(cfg: M.ModelConfig) -> str:
    return (
        f"model name={cfg.name} vocab={cfg.vocab} d_model={cfg.d_model} "
        f"n_layers={cfg.n_layers} n_heads={cfg.n_heads} d_head={cfg.d_head} "
        f"d_ff={cfg.d_ff} max_seq={cfg.max_seq} n_params={cfg.n_params}"
    )


def build_all(outdir: str, quick: bool = False) -> List[str]:
    os.makedirs(outdir, exist_ok=True)
    tiny_w = M.init_weights(M.TINY)
    draft_w = M.init_weights(M.DRAFT, seed=7)
    enc_w = M.init_encoder_weights(M.ENC)
    moe_w = M.init_moe_weights(M.MOE)

    n = write_weights(
        os.path.join(outdir, "weights.bin"),
        [("tiny", tiny_w), ("draft", draft_w), ("enc", enc_w), ("moe", moe_w)],
    )

    manifest: List[str] = [
        model_manifest_line(M.TINY),
        model_manifest_line(M.DRAFT),
        f"model name=enc n_patches={M.ENC.n_patches} d_patch={M.ENC.d_patch} "
        f"d_model={M.ENC.d_model}",
        f"model name=moe n_experts={M.MOE.n_experts} d_model={M.MOE.d_model} "
        f"d_ff={M.MOE.d_ff} n_tokens={M.MOE.n_tokens}",
        f"weights file=weights.bin n_tensors={n}",
    ]

    jobs: List[Tuple[str, Callable[[], str], str]] = []

    prefill_buckets = PREFILL_BUCKETS[:1] if quick else PREFILL_BUCKETS
    decode_buckets = DECODE_BUCKETS[:1] if quick else DECODE_BUCKETS
    verify_buckets = VERIFY_BUCKETS[:1] if quick else VERIFY_BUCKETS
    draft_buckets = DRAFT_DECODE_BUCKETS[:1] if quick else DRAFT_DECODE_BUCKETS

    for s in prefill_buckets:
        name = f"prefill_s{s}"
        jobs.append(
            (
                name,
                lambda s=s: lower_graph(
                    lambda ws, t: M.prefill(ws, M.TINY, t),
                    tiny_w,
                    [spec((s,), jnp.int32)],
                ),
                f"weights=tiny kind=prefill s={s}",
            )
        )
    for b in decode_buckets:
        name = f"decode_b{b}"
        jobs.append(
            (
                name,
                lambda b=b: lower_graph(
                    lambda ws, t, p, k, v: M.decode(ws, M.TINY, t, p, k, v),
                    tiny_w,
                    [
                        spec((b,), jnp.int32),
                        spec((b,), jnp.int32),
                        cache_spec(M.TINY, b),
                        cache_spec(M.TINY, b),
                    ],
                ),
                f"weights=tiny kind=decode b={b} smax={M.TINY.max_seq}",
            )
        )
    for b, m in verify_buckets:
        name = f"verify_b{b}_m{m}"
        jobs.append(
            (
                name,
                lambda b=b, m=m: lower_graph(
                    lambda ws, t, p, k, v: M.verify(ws, M.TINY, t, p, k, v),
                    tiny_w,
                    [
                        spec((b, m), jnp.int32),
                        spec((b,), jnp.int32),
                        cache_spec(M.TINY, b),
                        cache_spec(M.TINY, b),
                    ],
                ),
                f"weights=tiny kind=verify b={b} m={m} smax={M.TINY.max_seq}",
            )
        )
    for b in draft_buckets:
        name = f"draft_decode_b{b}"
        jobs.append(
            (
                name,
                lambda b=b: lower_graph(
                    lambda ws, t, p, k, v: M.decode(ws, M.DRAFT, t, p, k, v),
                    draft_w,
                    [
                        spec((b,), jnp.int32),
                        spec((b,), jnp.int32),
                        cache_spec(M.DRAFT, b),
                        cache_spec(M.DRAFT, b),
                    ],
                ),
                f"weights=draft kind=decode b={b} smax={M.DRAFT.max_seq}",
            )
        )
    jobs.append(
        (
            "encode",
            lambda: lower_graph(
                lambda ws, p: M.encode(ws, M.ENC, p),
                enc_w,
                [spec((M.ENC.n_patches, M.ENC.d_patch))],
            ),
            f"weights=enc kind=encode np={M.ENC.n_patches} dp={M.ENC.d_patch}",
        )
    )
    jobs.append(
        (
            "moe",
            lambda: lower_graph(
                lambda ws, x: M.moe_block(ws, M.MOE, x),
                moe_w,
                [spec((M.MOE.n_tokens, M.MOE.d_model))],
            ),
            f"weights=moe kind=moe t={M.MOE.n_tokens} d={M.MOE.d_model}",
        )
    )

    written = []
    for name, build, extra in jobs:
        fname = f"{name}.hlo.txt"
        text = build()
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        manifest.append(f"graph name={name} file={fname} {extra}")
        written.append(fname)
        print(f"  lowered {name}: {len(text)} chars", file=sys.stderr)

    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument(
        "--quick", action="store_true", help="only first bucket per graph (tests)"
    )
    args = ap.parse_args()
    written = build_all(args.outdir, quick=args.quick)
    print(f"wrote {len(written)} HLO modules + weights.bin + manifest.txt to {args.outdir}")


if __name__ == "__main__":
    main()
