"""L2: JAX transformer model for the xLLM reproduction (build-time only).

A tiny Qwen-style decoder-only transformer (RMSNorm, GELU MLP, learned
positional embeddings) whose attention hot-spots are the L1 Pallas kernels
in ``kernels/attention.py``.  ``aot.py`` lowers the graphs below ONCE to
HLO text; the rust runtime loads and executes them — Python never appears
on the request path.

Graphs (all pure functions of (weights, inputs), all returning tuples):

* ``prefill(w, tokens[S])``                    -> (logits[S,V], k, v)
* ``decode(w, tokens[B], pos[B], k, v)``       -> (logits[B,V], k', v')
* ``verify(w, tokens[B,M], pos[B], k, v)``     -> (logits[B,M,V], k', v')
* ``encode(ew, patches[Np,Dp])``               -> (emb[Np,D],)
* ``moe_block(mw, x[T,D])``                    -> (y[T,D],)

KV cache layout is [L, B, H, Smax, Dh] — the *contiguous view* the xTensor
manager (rust, §4.3) presents to kernels.  Cache updates use one-hot
scatter so every graph stays shape-static per (S or B) bucket, which is
what the rust Adaptive Graph Mode caches one executable for.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as ka
from .kernels import moe as km


@dataclass(frozen=True)
class ModelConfig:
    """Configuration of the tiny serving model."""

    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_head: int = 16
    d_ff: int = 256
    max_seq: int = 160  # Smax: prompt bucket (<=128) + decode budget (32)
    name: str = "tiny"

    @property
    def params_per_layer(self) -> int:
        d = self.d_model
        return 4 * d * d + 2 * d * self.d_ff + 2 * d

    @property
    def n_params(self) -> int:
        return (
            2 * self.vocab * self.d_model
            + self.max_seq * self.d_model
            + self.n_layers * self.params_per_layer
            + self.d_model
        )


@dataclass(frozen=True)
class EncoderConfig:
    """Tiny 'vision' encoder: 2-layer MLP patch embedder (EPD experiments)."""

    n_patches: int = 16
    d_patch: int = 32
    d_hidden: int = 128
    d_model: int = 64
    name: str = "enc"


@dataclass(frozen=True)
class MoeConfig:
    """Standalone MoE block (EPLB experiments)."""

    n_experts: int = 4
    d_model: int = 64
    d_ff: int = 128
    n_tokens: int = 32
    name: str = "moe"


TINY = ModelConfig()
DRAFT = ModelConfig(n_layers=1, d_model=32, n_heads=2, d_head=16, d_ff=128, name="draft")
ENC = EncoderConfig()
MOE = MoeConfig()

# A weight set is an ordered list of (name, array); order defines the HLO
# parameter order that the rust runtime must follow (see manifest).
Weights = List[Tuple[str, jax.Array]]


def init_weights(cfg: ModelConfig, seed: int = 0) -> Weights:
    """Deterministic (seeded) init of all model weights, as an ordered list."""
    rng = np.random.default_rng(seed)
    d, v = cfg.d_model, cfg.vocab

    def w(*shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return jnp.asarray(rng.normal(0.0, scale, shape), jnp.float32)

    ws: Weights = [
        ("embed", w(v, d, scale=0.02)),
        ("pos_embed", w(cfg.max_seq, d, scale=0.02)),
    ]
    for i in range(cfg.n_layers):
        ws += [
            (f"l{i}.wq", w(d, d)),
            (f"l{i}.wk", w(d, d)),
            (f"l{i}.wv", w(d, d)),
            (f"l{i}.wo", w(d, d)),
            (f"l{i}.ln1", jnp.ones((d,), jnp.float32)),
            (f"l{i}.ln2", jnp.ones((d,), jnp.float32)),
            (f"l{i}.w1", w(d, cfg.d_ff)),
            (f"l{i}.w2", w(cfg.d_ff, d)),
        ]
    ws += [
        ("ln_f", jnp.ones((d,), jnp.float32)),
        ("unembed", w(d, v, scale=0.02)),
    ]
    return ws


def init_encoder_weights(cfg: EncoderConfig, seed: int = 1) -> Weights:
    rng = np.random.default_rng(seed)

    def w(*shape):
        return jnp.asarray(rng.normal(0.0, 1.0 / np.sqrt(shape[0]), shape), jnp.float32)

    return [
        ("enc.w1", w(cfg.d_patch, cfg.d_hidden)),
        ("enc.b1", jnp.zeros((cfg.d_hidden,), jnp.float32)),
        ("enc.w2", w(cfg.d_hidden, cfg.d_model)),
        ("enc.b2", jnp.zeros((cfg.d_model,), jnp.float32)),
    ]


def init_moe_weights(cfg: MoeConfig, seed: int = 2) -> Weights:
    rng = np.random.default_rng(seed)

    def w(*shape, scale=0.1):
        return jnp.asarray(rng.normal(0.0, scale, shape), jnp.float32)

    return [
        ("moe.gate", w(cfg.d_model, cfg.n_experts)),
        ("moe.w1", w(cfg.n_experts, cfg.d_model, cfg.d_ff)),
        ("moe.b1", jnp.zeros((cfg.n_experts, cfg.d_ff), jnp.float32)),
        ("moe.w2", w(cfg.n_experts, cfg.d_ff, cfg.d_model)),
        ("moe.b2", jnp.zeros((cfg.n_experts, cfg.d_model), jnp.float32)),
    ]


def _wd(ws: Weights) -> Dict[str, jax.Array]:
    return dict(ws)


def rms_norm(x: jax.Array, g: jax.Array) -> jax.Array:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _split_heads(x: jax.Array, h: int, dh: int) -> jax.Array:
    """[..., D] -> [..., H, Dh]."""
    return x.reshape(x.shape[:-1] + (h, dh))


def prefill(ws: Weights, cfg: ModelConfig, tokens: jax.Array):
    """Prefill a single prompt of (padded) length S.

    Args:
      tokens: int32[S], padded with anything past the true length; padded
        positions never influence earlier positions under the causal mask.
    Returns:
      (logits f32[S, V]  — per-position logits (caller picks length-1),
       k f32[L, H, S, Dh], v f32[L, H, S, Dh]).
    """
    w = _wd(ws)
    h, dh = cfg.n_heads, cfg.d_head
    s = tokens.shape[0]
    x = w["embed"][tokens] + w["pos_embed"][:s]
    ks, vs = [], []
    for i in range(cfg.n_layers):
        xa = rms_norm(x, w[f"l{i}.ln1"])
        q = _split_heads(xa @ w[f"l{i}.wq"], h, dh).transpose(1, 0, 2)  # [H,S,Dh]
        k = _split_heads(xa @ w[f"l{i}.wk"], h, dh).transpose(1, 0, 2)
        v = _split_heads(xa @ w[f"l{i}.wv"], h, dh).transpose(1, 0, 2)
        o = ka.mha_prefill(q, k, v)  # [H,S,Dh]  (L1 Pallas kernel)
        x = x + o.transpose(1, 0, 2).reshape(s, -1) @ w[f"l{i}.wo"]
        xm = rms_norm(x, w[f"l{i}.ln2"])
        x = x + jax.nn.gelu(xm @ w[f"l{i}.w1"]) @ w[f"l{i}.w2"]
        ks.append(k)
        vs.append(v)
    logits = rms_norm(x, w["ln_f"]) @ w["unembed"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def _write_cache(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Scatter new KV rows at per-sequence positions via one-hot.

    cache: [B, H, Smax, Dh]; new: [B, H, Dh]; pos: [B] -> updated cache.
    """
    smax = cache.shape[2]
    onehot = jax.nn.one_hot(pos, smax, dtype=cache.dtype)  # [B, Smax]
    oh = onehot[:, None, :, None]
    return cache * (1.0 - oh) + new[:, :, None, :] * oh


def decode(ws: Weights, cfg: ModelConfig, tokens, pos, k_cache, v_cache):
    """One decode step for a batch of B sequences.

    Args:
      tokens: int32[B] current token ids.
      pos: int32[B] cache position of the current token.
      k_cache, v_cache: f32[L, B, H, Smax, Dh].
    Returns:
      (logits f32[B, V], k', v').
    """
    w = _wd(ws)
    h, dh = cfg.n_heads, cfg.d_head
    x = w["embed"][tokens] + w["pos_embed"][pos]  # [B, D]
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        xa = rms_norm(x, w[f"l{i}.ln1"])
        q = _split_heads(xa @ w[f"l{i}.wq"], h, dh)  # [B,H,Dh]
        kn = _split_heads(xa @ w[f"l{i}.wk"], h, dh)
        vn = _split_heads(xa @ w[f"l{i}.wv"], h, dh)
        kc = _write_cache(k_cache[i], kn, pos)
        vc = _write_cache(v_cache[i], vn, pos)
        o = ka.decode_attention(q, kc, vc, pos)  # [B,H,Dh]  (L1 kernel)
        x = x + o.reshape(x.shape[0], -1) @ w[f"l{i}.wo"]
        xm = rms_norm(x, w[f"l{i}.ln2"])
        x = x + jax.nn.gelu(xm @ w[f"l{i}.w1"]) @ w[f"l{i}.w2"]
        new_k.append(kc)
        new_v.append(vc)
    logits = rms_norm(x, w["ln_f"]) @ w["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def verify(ws: Weights, cfg: ModelConfig, tokens, pos, k_cache, v_cache):
    """Speculative verify: score M candidate tokens per sequence in one pass.

    Args:
      tokens: int32[B, M] candidate tokens (token j sits at cache pos+j).
      pos: int32[B] cache position of candidate 0.
      k_cache, v_cache: f32[L, B, H, Smax, Dh].
    Returns:
      (logits f32[B, M, V], k', v') — caches updated at pos..pos+M-1.
    """
    w = _wd(ws)
    h, dh = cfg.n_heads, cfg.d_head
    b, m = tokens.shape
    positions = pos[:, None] + jnp.arange(m)[None, :]  # [B, M]
    x = w["embed"][tokens] + w["pos_embed"][positions]  # [B, M, D]
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        xa = rms_norm(x, w[f"l{i}.ln1"])
        q = _split_heads(xa @ w[f"l{i}.wq"], h, dh)  # [B,M,H,Dh]
        kn = _split_heads(xa @ w[f"l{i}.wk"], h, dh)
        vn = _split_heads(xa @ w[f"l{i}.wv"], h, dh)
        kc, vc = k_cache[i], v_cache[i]
        for j in range(m):  # M is small (<=4); unrolled scatter
            kc = _write_cache(kc, kn[:, j], pos + j)
            vc = _write_cache(vc, vn[:, j], pos + j)
        o = ka.spec_attention(q, kc, vc, pos)  # [B,M,H,Dh]  (L1 kernel)
        x = x + o.reshape(b, m, -1) @ w[f"l{i}.wo"]
        xm = rms_norm(x, w[f"l{i}.ln2"])
        x = x + jax.nn.gelu(xm @ w[f"l{i}.w1"]) @ w[f"l{i}.w2"]
        new_k.append(kc)
        new_v.append(vc)
    logits = rms_norm(x, w["ln_f"]) @ w["unembed"]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def encode(ws: Weights, cfg: EncoderConfig, patches: jax.Array):
    """Tiny vision encoder: patches [Np, Dp] -> (embeddings [Np, D],)."""
    w = _wd(ws)
    hdn = jax.nn.gelu(patches @ w["enc.w1"] + w["enc.b1"])
    return (hdn @ w["enc.w2"] + w["enc.b2"],)


def moe_block(ws: Weights, cfg: MoeConfig, x: jax.Array):
    """Standalone top-1 MoE FFN block: x [T, D] -> (y [T, D],)."""
    w = _wd(ws)
    expert = km.route_top1(x, w["moe.gate"])
    y = km.moe_ffn(x, w["moe.w1"], w["moe.b1"], w["moe.w2"], w["moe.b2"], expert)
    return (y,)
