"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: simple, obviously-right einsum
formulations with no tiling, no pallas, no tricks.  pytest + hypothesis
(python/tests/test_kernels.py) sweeps shapes and checks allclose against the
kernels in attention.py / moe.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal MHA. q/k/v: [H, S, Dh] -> [H, S, Dh]."""
    h, s, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    row = jnp.arange(s)[:, None]
    col = jnp.arange(s)[None, :]
    logits = jnp.where(col <= row, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array
) -> jax.Array:
    """Decode attention. q: [B,H,Dh], k/v: [B,H,S,Dh], pos: [B] -> [B,H,Dh]."""
    b, h, s, dh = k.shape
    scale = 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    idx = jnp.arange(s)[None, None, :]
    logits = jnp.where(idx <= pos[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def spec_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array
) -> jax.Array:
    """Speculative multi-Q attention.

    q: [B,M,H,Dh], k/v: [B,H,S,Dh], pos: [B] -> [B,M,H,Dh].
    Token j attends to cache slots [0, pos+j].
    """
    b, mm, h, dh = q.shape
    s = k.shape[2]
    scale = 1.0 / (dh ** 0.5)
    logits = (
        jnp.einsum("bmhd,bhsd->bmhs", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    )
    sidx = jnp.arange(s)[None, None, None, :]
    limit = (pos[:, None] + jnp.arange(mm)[None, :])[:, :, None, None]
    logits = jnp.where(sidx <= limit, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bmhs,bhsd->bmhd", p, v.astype(jnp.float32)).astype(q.dtype)


def moe_ffn_ref(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    expert: jax.Array,
) -> jax.Array:
    """Top-1 MoE FFN oracle. Shapes as in moe.moe_ffn."""
    xf = x.astype(jnp.float32)
    h = jax.nn.gelu(jnp.einsum("td,edf->tef", xf, w1.astype(jnp.float32)) + b1[None])
    y = jnp.einsum("tef,efd->ted", h, w2.astype(jnp.float32)) + b2[None]
    onehot = jax.nn.one_hot(expert, w1.shape[0], dtype=jnp.float32)  # [T, E]
    return jnp.einsum("ted,te->td", y, onehot).astype(x.dtype)
