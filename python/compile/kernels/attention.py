"""L1 Pallas attention kernels for the xLLM reproduction.

Three kernels, all written against a *contiguous* KV view — this is the
xTensor contract from the paper (§4.3): the kernel sees one logically
contiguous [S, Dh] (or [B, S, Dh]) KV region per head and takes *no block
table*; discreteness of the underlying physical pages is the runtime's
problem, not the kernel's.  This is exactly the paper's reconstructed
"contiguous FlashMLA" operator: block-table queries and cross-page boundary
checks are removed from the hot loop.

Hardware adaptation (paper targets Ascend Cube/Vector units; our structural
target is the TPU MXU/VPU via Pallas):

* ``mha_prefill``      — causal self-attention over a full prompt.  Grid is
  over heads; each program holds the whole (S, Dh) tile in VMEM.  For the
  bucketed prompt lengths used by the AOT path (S <= 128, Dh = 16) the
  working set is S*Dh*3*4B  < 25 KB — far under the ~16 MB VMEM budget, so a
  single-block schedule is the roofline-optimal choice (no HBM re-streaming).
* ``decode_attention`` — one new token per sequence against the cache, with
  per-sequence valid-length masking (the "logically contiguous" view over
  physically discrete pages).
* ``spec_attention``   — the paper's §4.4.1 MLA speculative-decoding
  optimization rethought for a VMEM machine: all m+1 speculative Q rows are
  tiled into ONE resident block (the paper's "Q matrix cache residency"),
  and K/V are streamed exactly once per head (the paper's "reduced K matrix
  loading" via sliding windows).  In BlockSpec terms: Q block = [B, M, Dh]
  stays in VMEM for the whole contraction; K block = [B, S, Dh] makes a
  single HBM->VMEM pass.

All kernels MUST run with ``interpret=True``: real-TPU lowering emits a
Mosaic custom-call that the CPU PJRT plugin cannot execute.  Correctness is
pinned against ``ref.py`` by ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mha_prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One head of causal attention. Blocks: q/k/v/o = [S, Dh]."""
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    s = q.shape[0]
    logits = (q @ k.T) * scale  # [S, S]
    row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    logits = jnp.where(col <= row, logits, NEG_INF)
    # numerically stable softmax
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    o = (p @ v) / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o.astype(o_ref.dtype)


def mha_prefill(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Causal multi-head attention over a full prompt.

    Args:
      q, k, v: [H, S, Dh].
    Returns:
      [H, S, Dh] attention output.
    """
    h, s, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    spec = pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0))

    def kernel(q_ref, k_ref, v_ref, o_ref):
        _mha_prefill_kernel(
            q_ref.at[0], k_ref.at[0], v_ref.at[0], o_ref.at[0], scale=scale
        )

    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((h, s, dh), q.dtype),
        interpret=True,
    )(q, k, v)


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, scale: float):
    """One head of single-token decode attention over a length-masked cache.

    Blocks: q = [B, Dh], k/v = [B, S, Dh], pos = [B], o = [B, Dh].
    Token at step t attends to cache slots [0, pos] inclusive (the new
    token's K/V has already been written at index pos by the caller).
    """
    q = q_ref[...].astype(jnp.float32)  # [B, Dh]
    k = k_ref[...].astype(jnp.float32)  # [B, S, Dh]
    v = v_ref[...].astype(jnp.float32)
    pos = pos_ref[...]  # [B]
    b, s, _ = k.shape
    logits = jnp.einsum("bd,bsd->bs", q, k) * scale  # [B, S]
    idx = jax.lax.broadcasted_iota(jnp.int32, (b, s), 1)
    logits = jnp.where(idx <= pos[:, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    o = jnp.einsum("bs,bsd->bd", p, v) / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o.astype(o_ref.dtype)


def decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array
) -> jax.Array:
    """Single-token decode attention against a contiguous KV cache view.

    Args:
      q: [B, H, Dh] query for the token being generated.
      k, v: [B, H, S, Dh] KV cache (token for ``pos`` already written).
      pos: [B] int32, index of the current token in the cache.
    Returns:
      [B, H, Dh].
    """
    b, h, s, dh = k.shape
    scale = 1.0 / (dh ** 0.5)
    q_spec = pl.BlockSpec((b, 1, dh), lambda i: (0, i, 0))
    kv_spec = pl.BlockSpec((b, 1, s, dh), lambda i: (0, i, 0, 0))
    pos_spec = pl.BlockSpec((b,), lambda i: (0,))

    def kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
        _decode_kernel(
            q_ref.at[:, 0],
            k_ref.at[:, 0],
            v_ref.at[:, 0],
            pos_ref,
            o_ref.at[:, 0],
            scale=scale,
        )

    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[q_spec, kv_spec, kv_spec, pos_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=True,
    )(q, k, v, pos)


def _spec_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, scale: float):
    """One head of multi-Q speculative attention.

    Blocks: q = [B, M, Dh], k/v = [B, S, Dh], pos = [B], o = [B, M, Dh].
    Speculative token j (0-based) of sequence b attends to cache slots
    [0, pos[b] + j] inclusive.  The whole Q tile stays resident while K is
    contracted in one pass — the Pallas re-expression of the paper's
    "Q cache residency + reduced K loads" MLA optimization.
    """
    q = q_ref[...].astype(jnp.float32)  # [B, M, Dh]
    k = k_ref[...].astype(jnp.float32)  # [B, S, Dh]
    v = v_ref[...].astype(jnp.float32)
    pos = pos_ref[...]  # [B]
    b, mm, _ = q.shape
    s = k.shape[1]
    logits = jnp.einsum("bmd,bsd->bms", q, k) * scale  # [B, M, S]
    midx = jax.lax.broadcasted_iota(jnp.int32, (b, mm, s), 1)
    sidx = jax.lax.broadcasted_iota(jnp.int32, (b, mm, s), 2)
    limit = pos[:, None, None] + midx
    logits = jnp.where(sidx <= limit, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    o = jnp.einsum("bms,bsd->bmd", p, v) / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o.astype(o_ref.dtype)


def spec_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array
) -> jax.Array:
    """Multi-token (speculative verify) attention over a contiguous cache.

    Args:
      q: [B, M, H, Dh] queries for M = m+1 speculative tokens.
      k, v: [B, H, S, Dh] cache with the M speculative tokens already written
        at positions pos .. pos+M-1.
      pos: [B] int32 position of the FIRST speculative token.
    Returns:
      [B, M, H, Dh].
    """
    b, mm, h, dh = q.shape
    s = k.shape[2]
    scale = 1.0 / (dh ** 0.5)
    q_spec = pl.BlockSpec((b, mm, 1, dh), lambda i: (0, 0, i, 0))
    kv_spec = pl.BlockSpec((b, 1, s, dh), lambda i: (0, i, 0, 0))
    pos_spec = pl.BlockSpec((b,), lambda i: (0,))

    def kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
        _spec_kernel(
            q_ref.at[:, :, 0],
            k_ref.at[:, 0],
            v_ref.at[:, 0],
            pos_ref,
            o_ref.at[:, :, 0],
            scale=scale,
        )

    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[q_spec, kv_spec, kv_spec, pos_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((b, mm, h, dh), q.dtype),
        interpret=True,
    )(q, k, v, pos)
