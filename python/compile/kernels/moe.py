"""L1 Pallas grouped-expert MoE FFN kernel.

Implements the expert-parallel compute primitive behind the paper's dynamic
EPLB (§4.4.2): tokens are routed (top-1) to E experts and each expert
applies its own 2-layer GELU FFN.  The kernel iterates the grid over
experts; each program applies its expert's weights to the *whole* token
block under a routing mask and accumulates into the shared output tile.
This is the dense-masked formulation (every expert touches every token tile
with a 0/1 mask) — the standard Pallas/TPU idiom replacing the GPU
gather/scatter formulation, and the one whose per-expert token counts the
rust EPLB layer balances.

interpret=True only (see attention.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _moe_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, expert_ref, o_ref):
    """Grid over experts; accumulate masked expert FFN outputs.

    Blocks: x = [T, D], w1 = [D, F], b1 = [F], w2 = [F, D], b2 = [D],
    expert = [T] int32 routing decisions, o = [T, D].
    """
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    h = jax.nn.gelu(x @ w1_ref[...].astype(jnp.float32) + b1_ref[...])
    y = h @ w2_ref[...].astype(jnp.float32) + b2_ref[...]
    mask = (expert_ref[...] == e).astype(jnp.float32)[:, None]
    o_ref[...] += (y * mask).astype(o_ref.dtype)


def moe_ffn(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    expert: jax.Array,
) -> jax.Array:
    """Top-1 routed mixture-of-experts FFN.

    Args:
      x: [T, D] token activations.
      w1: [E, D, F]; b1: [E, F]; w2: [E, F, D]; b2: [E, D] per-expert FFN.
      expert: [T] int32 in [0, E) — routing decision per token.
    Returns:
      [T, D].
    """
    e, d, f = w1.shape
    t = x.shape[0]
    x_spec = pl.BlockSpec((t, d), lambda i: (0, 0))
    w1_spec = pl.BlockSpec((1, d, f), lambda i: (i, 0, 0))
    b1_spec = pl.BlockSpec((1, f), lambda i: (i, 0))
    w2_spec = pl.BlockSpec((1, f, d), lambda i: (i, 0, 0))
    b2_spec = pl.BlockSpec((1, d), lambda i: (i, 0))
    r_spec = pl.BlockSpec((t,), lambda i: (0,))

    def kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, expert_ref, o_ref):
        _moe_kernel(
            x_ref,
            w1_ref.at[0],
            b1_ref.at[0],
            w2_ref.at[0],
            b2_ref.at[0],
            expert_ref,
            o_ref,
        )

    return pl.pallas_call(
        kernel,
        grid=(e,),
        in_specs=[x_spec, w1_spec, b1_spec, w2_spec, b2_spec, r_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2, expert)


def route_top1(x: jax.Array, w_gate: jax.Array) -> jax.Array:
    """Top-1 router: argmax of the gating logits. x: [T, D], w_gate: [D, E]."""
    return jnp.argmax(x @ w_gate, axis=-1).astype(jnp.int32)
