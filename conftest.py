"""Pytest root conftest: make `compile.*` importable when running
`pytest python/tests/` from the repository root (tests live under
python/ and import the build-path package directly)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
